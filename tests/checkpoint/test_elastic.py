"""Elastic restore: save under one sharding, restore under another.

Runs in a subprocess with 8 forced host devices so real multi-device
shardings exist (the main pytest process keeps 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import ChunkStore, save_pytree, restore_pytree
    from repro.launch.mesh import make_mesh

    root = tempfile.mkdtemp()
    store = ChunkStore(root)

    mesh_a = make_mesh((4, 2), ("x", "y"))
    sh_a = NamedSharding(mesh_a, P("x", "y"))
    w = jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16)
    state = {
        "w": jax.device_put(w, sh_a),
        "r": jax.device_put(jnp.arange(8.0), NamedSharding(mesh_a, P())),  # replicated
        "host": np.int64(5),
    }
    save_pytree(state, store, 1, chunk_bytes=256)

    # restore on a DIFFERENT mesh & layout
    mesh_b = make_mesh((8,), ("z",))
    sh_b = {
        "w": NamedSharding(mesh_b, P(None, "z")),
        "r": NamedSharding(mesh_b, P("z")),
        "host": None,
    }
    restored, m = restore_pytree(store, 1, sh_b, verify_digests=True)
    ok_w = bool(jnp.array_equal(jnp.asarray(restored["w"]), w))
    ok_r = bool(jnp.array_equal(jnp.asarray(restored["r"]), jnp.arange(8.0)))
    ok_h = int(restored["host"]) == 5
    ok_sh = restored["w"].sharding.is_equivalent_to(sh_b["w"], 2)
    # host-only restore (no shardings at all)
    full_np, _ = restore_pytree(store, 1)
    ok_np = bool(np.array_equal(full_np["w"], np.asarray(w)))
    print(json.dumps({"ok": ok_w and ok_r and ok_h and ok_sh and ok_np}))
    """
)


@pytest.mark.slow
def test_elastic_reshard_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        ),
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"]
