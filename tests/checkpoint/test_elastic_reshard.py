"""Elastic reshard restore: a committed N-host image re-sliced onto M.

The manifest is topology-independent; ``RestoreManager.restore_elastic``
re-slices it with the SAME ownership rule the writers use
(``host_slice_plan``), so the acceptance here is exhaustive coverage:
non-divisible splits in both directions, single-host collapse, and a
delta chain surviving GC under the new slicing.
"""
import os

import numpy as np
import pytest

from repro.checkpoint.manifest import commit_manifest, merge_hostmetas
from repro.checkpoint.sharded import host_slice_plan
from repro.checkpoint.store import ChunkStore
from repro.core.forked import ForkedCheckpointer
from repro.core.policy import CheckpointPolicy
from repro.core.restore import RestoreManager
from repro.core.shadow import HostShardView
from repro.coord.worker import shard_tree_for_host, state_digest
from repro.utils.tree import flatten_with_paths


def _state(seed=0, rows=12, cols=16):
    rng = np.random.default_rng(seed)
    return {
        "device": {
            "w": rng.standard_normal((rows, cols)).astype(np.float32),
            "b": rng.standard_normal((cols,)).astype(np.float32),
            "scale": np.float32(1.25),
        },
        "host": {"step": np.int64(7)},
    }


def _commit_over_hosts(root, state, step, n_hosts, *, cks=None,
                       incremental=False):
    """Persist + merge + commit one image across n_hosts (thread backend)."""
    cks = cks if cks is not None else {}
    for h in range(n_hosts):
        ck = cks.get(h)
        if ck is None:
            ck = cks[h] = ForkedCheckpointer(
                ChunkStore(root), chunk_bytes=1 << 7, host=h,
                backend="thread", external_commit=True,
                digest_on_device=False, incremental=incremental,
            )
        ck.save_async(step, shard_tree_for_host(state, h, n_hosts)).wait(60)
    commit_manifest(root, merge_hostmetas(root, step))
    for ck in cks.values():
        ck.commit_confirmed(step)
    return cks


def _reassemble(shard_trees):
    """Combine per-host HostShardView trees back into global arrays."""
    out = {}
    for tree in shard_trees:
        flat, _ = flatten_with_paths(tree)
        for path, view in flat.items():
            assert isinstance(view, HostShardView), path
            if path not in out:
                out[path] = (
                    np.full(view.shape, np.nan, dtype=view.dtype)
                    if view.shape else np.zeros((), view.dtype)
                )
            if view.data is None:
                continue
            if view.shape:
                idx = tuple(slice(a, b) for a, b in zip(view.start, view.stop))
                out[path][idx] = view.data
            else:
                out[path] = np.asarray(view.data, dtype=view.dtype).reshape(())
    return out


# -- the ownership rule itself ---------------------------------------------------

def test_host_slice_plan_partitions_exactly():
    """For ANY (n0, n_hosts): dim-0 windows tile [0, n0) without gaps or
    overlaps, and every small leaf has exactly one owner."""
    for n0 in (1, 5, 12, 13):
        for n in (1, 2, 3, 5, 8):
            if n0 >= n:
                edges = []
                for h in range(n):
                    plan = host_slice_plan("p", (n0, 4), h, n)
                    assert plan is not None
                    edges.append((plan[0][0], plan[1][0]))
                assert edges[0][0] == 0 and edges[-1][1] == n0
                for (a, b), (c, d) in zip(edges, edges[1:]):
                    assert b == c  # contiguous, no gap/overlap
            owners = [
                h for h in range(n)
                if host_slice_plan("tiny", (), h, n) is not None
            ]
            assert len(owners) == 1


def test_host_slice_plan_matches_live_sharding():
    """restore_elastic's plan == what shard_tree_for_host persists."""
    state = _state()
    flat, _ = flatten_with_paths(state)
    for n in (1, 2, 3, 5):
        for h in range(n):
            live, _ = flatten_with_paths(shard_tree_for_host(state, h, n))
            for path, view in live.items():
                plan = host_slice_plan(
                    path, np.asarray(flat[path]).shape, h, n
                )
                if view.data is None:
                    assert plan is None, (path, h, n)
                else:
                    assert plan == (view.start, view.stop), (path, h, n)


# -- reshard restores -------------------------------------------------------------

@pytest.mark.parametrize("n_old,n_new", [
    (4, 3),   # neither divides the other
    (3, 5),   # grow, non-divisible
    (4, 6),   # acceptance: 4-host image onto 6
    (4, 1),   # single-host collapse
    (5, 2),
])
def test_reshard_bit_identical(tmp_path, n_old, n_new):
    root = str(tmp_path / "ck")
    state = _state(rows=13)  # odd rows: every split is uneven somewhere
    cks = _commit_over_hosts(root, state, 5, n_old)
    rm = RestoreManager(ChunkStore(root))

    # full-state restore is host-count independent
    full, m = rm.restore_elastic(n_hosts=n_new)
    assert m.step == 5
    assert state_digest(full) == state_digest(state)

    # per-host slices under the NEW topology cover the image exactly
    trees = []
    for h in range(n_new):
        shard, m = rm.restore_elastic(n_hosts=n_new, host=h)
        trees.append(shard)
    merged = _reassemble(trees)
    flat, _ = flatten_with_paths(state)
    for path, leaf in flat.items():
        np.testing.assert_array_equal(merged[path], np.asarray(leaf),
                                      err_msg=path)

    # and the slices are exactly what n_new live writers would persist —
    # a restarted cluster can immediately checkpoint under the new count
    for h in range(n_new):
        live, _ = flatten_with_paths(shard_tree_for_host(state, h, n_new))
        got, _ = flatten_with_paths(trees[h])
        for path in live:
            if live[path].data is None:
                assert got[path].data is None
            else:
                np.testing.assert_array_equal(got[path].data, live[path].data)
                assert got[path].start == live[path].start
                assert got[path].stop == live[path].stop
    for ck in cks.values():
        ck.close()


def test_reshard_after_gc_of_delta_chain(tmp_path):
    """An incremental (delta) manifest re-slices correctly after GC has
    run: chunk references chase into the base step's files, which the
    reference closure keeps alive."""
    root = str(tmp_path / "ck")
    store = ChunkStore(root)
    state = _state(rows=12)
    cks = _commit_over_hosts(root, state, 1, 2, incremental=True)

    # step 2: mutate one row -> delta manifest referencing step 1 payloads
    state2 = {
        "device": dict(state["device"]), "host": {"step": np.int64(8)},
    }
    w2 = state2["device"]["w"].copy()
    w2[3] += 1.0
    state2["device"]["w"] = w2
    _commit_over_hosts(root, state2, 2, 2, cks=cks, incremental=True)

    # GC keep_last=1: step 2 survives, and because its delta references
    # step 1's payload files, the reference closure pins those too
    CheckpointPolicy(keep_last=1).run_gc(store)
    rm = RestoreManager(store)
    assert rm.available_steps()[-1] == 2

    # elastic restore of the delta image onto 3 hosts, bit-identical
    trees = [
        rm.restore_elastic(n_hosts=3, host=h, step=2)[0] for h in range(3)
    ]
    merged = _reassemble(trees)
    flat, _ = flatten_with_paths(state2)
    for path, leaf in flat.items():
        np.testing.assert_array_equal(merged[path], np.asarray(leaf),
                                      err_msg=path)
    for ck in cks.values():
        ck.close()


def test_restore_elastic_unknown_step_raises(tmp_path):
    root = str(tmp_path / "ck")
    os.makedirs(root)
    with pytest.raises(FileNotFoundError):
        RestoreManager(ChunkStore(root)).restore_elastic(n_hosts=2, host=0)
