"""GC vs incremental delta chains: bases must survive their dependents.

Two layers of protection, both tested:

  - store-level: ``ChunkStore.gc`` re-derives the reference closure of the
    surviving manifests, so even a naive keep list cannot strand a delta,
  - checkpointer-level: bases of *in-flight* (not yet committed, hence
    invisible on disk) delta persists are pinned via
    ``inflight_delta_bases()``, which ``trainer._gc`` feeds to the policy.
"""
import threading

import numpy as np
import pytest

from repro.checkpoint.manifest import committed_steps, load_manifest, referenced_steps
from repro.checkpoint.store import ChunkStore
from repro.core import CheckpointedTrainer, CheckpointPolicy
from repro.core.forked import (
    ForkedCheckpointer,
    ThreadPersistBackend,
    register_persist_backend,
)
from repro.core.restore import RestoreManager
from repro.utils.tree import tree_equal


def _state(step, n=4096):
    base = np.arange(n, dtype=np.float32)
    base[:8] += step  # small delta: most chunks reused
    return {"w": base, "step": np.int64(step)}


def test_store_gc_pins_delta_base(tmp_path):
    """The regression: restore a delta checkpoint after its predecessor was
    GC-eligible by the caller's naive keep list."""
    store = ChunkStore(str(tmp_path / "s"))
    ck = ForkedCheckpointer(store, chunk_bytes=1024, incremental=True)
    s1 = _state(1)
    ck.save_async(1, s1).wait()
    s2 = _state(2)
    r2 = ck.save_async(2, s2).wait()
    ck.close()
    assert r2.chunks_reused > 0, "step 2 must actually be a delta"
    assert 1 in referenced_steps(load_manifest(store.root, 2))

    removed = store.gc([2])  # naive keep list: step 1 looks collectable
    assert removed == []  # the safety net pinned it
    assert committed_steps(store.root) == [1, 2]

    restored, _ = RestoreManager(store).restore(step=2)
    assert tree_equal(restored, s2)

    # with nothing kept, nothing is pinned: both steps collect
    assert set(store.gc([])) == {1, 2}
    assert committed_steps(store.root) == []


def test_store_gc_pin_can_be_disabled(tmp_path):
    store = ChunkStore(str(tmp_path / "s"))
    ck = ForkedCheckpointer(store, chunk_bytes=1024, incremental=True)
    ck.save_async(1, _state(1)).wait()
    ck.save_async(2, _state(2)).wait()
    ck.close()
    assert store.gc([2], pin_referenced=False) == [1]  # the old behaviour


class _GatedBackend(ThreadPersistBackend):
    """Thread backend whose phase 2 blocks on a class-level gate — lets a
    test hold a persist 'in flight' deterministically."""

    name = "gated"
    gate = threading.Event()

    def _run(self, job):
        type(self).gate.wait(30)
        super()._run(job)


register_persist_backend(_GatedBackend.name, _GatedBackend, replace=True)


def test_inflight_delta_base_pinned_through_trainer_gc(tmp_path):
    """A delta persist that has not committed yet references a base only
    the checkpointer knows about; trainer._gc must keep that base alive
    even when the policy alone would collect it."""
    _GatedBackend.gate.clear()
    trainer = CheckpointedTrainer(
        None,
        store_root=str(tmp_path / "t"),
        policy=CheckpointPolicy(interval_steps=0, keep_last=1),
        chunk_bytes=1024,
        backend="gated",
    )
    ck = trainer.checkpointer
    store = trainer.store

    # steps 1 and 2 committed (gate open)
    _GatedBackend.gate.set()
    ck.save_async(1, _state(1)).wait()
    ck.save_async(2, _state(2)).wait()

    # step 3: held in flight, its delta base is the step-2 manifest
    _GatedBackend.gate.clear()
    r3 = ck.save_async(3, _state(3))
    bases = ck.inflight_delta_bases()
    assert 2 in bases

    # keep_last=1 alone would collect step 1 AND step 2 (only 2 is kept by
    # the policy; 1 is pinned by 2's references) — the in-flight pin is
    # what keeps 2 itself
    trainer._gc()
    assert 2 in committed_steps(store.root), "in-flight delta base collected"

    _GatedBackend.gate.set()
    r3.wait()
    trainer.finish()
    assert ck.inflight_delta_bases() == set()

    # the chain is intact: step 3 restores
    restored, _ = RestoreManager(store).restore(step=3)
    assert tree_equal(restored, _state(3))


def test_policy_extra_keep_closure(tmp_path):
    """extra_keep pins transitively: keeping a delta keeps its base."""
    store = ChunkStore(str(tmp_path / "p"))
    ck = ForkedCheckpointer(store, chunk_bytes=1024, incremental=True)
    ck.save_async(1, _state(1)).wait()   # full base
    ck.save_async(2, _state(2)).wait()   # delta on 1
    ck.close()
    # step 3 is a FULL image: keep_last=1 alone would collect 1 and 2
    ck_full = ForkedCheckpointer(store, chunk_bytes=1024, incremental=False)
    ck_full.save_async(3, _state(3)).wait()
    ck_full.close()

    policy = CheckpointPolicy(keep_last=1)
    policy.run_gc(store, extra_keep={2})
    # keep_last keeps 3 (self-contained); extra_keep pins 2, and the
    # closure must then also keep 2's base, step 1
    assert set(committed_steps(store.root)) == {1, 2, 3}

    # without the extra pin, the window alone survives
    assert set(policy.run_gc(store)) == {1, 2}
    assert committed_steps(store.root) == [3]
