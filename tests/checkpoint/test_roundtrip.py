"""Checkpoint substrate: roundtrips, codecs, atomic commit, deltas, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.testing import given, settings, st

from repro.checkpoint import (
    ChunkStore,
    get_codec,
    latest_committed_step,
    list_codecs,
    load_manifest,
    restore_pytree,
    save_pytree,
)
from repro.checkpoint.manifest import committed_steps, is_committed, step_dir
from repro.utils.tree import tree_equal


def _state():
    return {
        "params": {
            "w": jnp.arange(1000, dtype=jnp.bfloat16).reshape(10, 100),
            "b": jnp.ones((7,), jnp.float32),
        },
        "step": np.int64(42),
        "nested": [jnp.zeros((3, 3), jnp.int32), (jnp.ones(5),)],
    }


def test_roundtrip_mixed_dtypes(tmp_store):
    state = _state()
    save_pytree(state, tmp_store, 1, chunk_bytes=128)
    restored, m = restore_pytree(tmp_store, 1, verify_digests=True)
    assert tree_equal(jax.tree.map(np.asarray, state), restored)
    assert m.step == 1


@pytest.mark.parametrize("codec", list_codecs())
def test_all_codecs_roundtrip(tmp_store, codec, rng):
    state = {"x": jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)}
    save_pytree(state, tmp_store, 2, codec=codec, chunk_bytes=4096)
    restored, _ = restore_pytree(tmp_store, 2, verify_digests=True)
    assert tree_equal(jax.tree.map(np.asarray, state), restored)


@pytest.mark.parametrize("codec", list_codecs())
def test_codec_inverse_property(codec, rng):
    c = get_codec(codec)
    for n in (0, 1, 100, 1 << 16, (1 << 20) + 13):
        data = rng.integers(0, 256, n).astype(np.uint8).tobytes()
        assert c.decompress(c.compress(data)) == data


def test_incremental_delta_reuses_clean_chunks(tmp_store):
    state = _state()
    m1 = save_pytree(state, tmp_store, 1, chunk_bytes=128)
    state2 = dict(state)
    state2["params"] = dict(state["params"])
    state2["params"]["b"] = state["params"]["b"] + 1
    m2 = save_pytree(state2, tmp_store, 2, chunk_bytes=128, prev_manifest=m1)
    assert m2.meta["chunks_reused"] > 0
    assert m2.meta["chunks_written"] < m1.meta["chunks_written"]
    restored, _ = restore_pytree(tmp_store, 2, verify_digests=True)
    assert tree_equal(jax.tree.map(np.asarray, state2), restored)


def test_uncommitted_checkpoint_is_invisible(tmp_store):
    state = _state()
    save_pytree(state, tmp_store, 1)
    save_pytree(state, tmp_store, 2, commit=False)
    assert latest_committed_step(tmp_store.root) == 1
    with pytest.raises(FileNotFoundError):
        load_manifest(tmp_store.root, 2)


def test_crash_mid_write_preserves_previous(tmp_store):
    """Simulate the forked child dying: truncate step-2 payload pre-commit."""
    state = _state()
    save_pytree(state, tmp_store, 1)
    save_pytree(state, tmp_store, 2, commit=False)
    # corrupt the in-flight step's data file, as a crash would
    d = step_dir(tmp_store.root, 2)
    for name in os.listdir(d):
        with open(os.path.join(d, name), "r+b") as f:
            f.truncate(3)
    # restore still lands on step 1, bit-exact
    restored, m = restore_pytree(tmp_store, latest_committed_step(tmp_store.root))
    assert m.step == 1
    assert tree_equal(jax.tree.map(np.asarray, state), restored)


def test_digest_verification_catches_corruption(tmp_store):
    state = _state()
    save_pytree(state, tmp_store, 1, codec="none")
    d = step_dir(tmp_store.root, 1)
    data_file = [n for n in os.listdir(d) if n.startswith("data-")][0]
    with open(os.path.join(d, data_file), "r+b") as f:
        f.seek(10)
        b = f.read(1)
        f.seek(10)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(IOError, match="digest mismatch"):
        restore_pytree(tmp_store, 1, verify_digests=True)


def test_gc_keeps_delta_closure(tmp_store):
    from repro.core.policy import CheckpointPolicy

    state = _state()
    m1 = save_pytree(state, tmp_store, 1, chunk_bytes=128)
    m2 = save_pytree(state, tmp_store, 2, chunk_bytes=128, prev_manifest=m1)
    m3 = save_pytree(state, tmp_store, 3, chunk_bytes=128, prev_manifest=m2)
    policy = CheckpointPolicy(keep_last=1)
    committed = committed_steps(tmp_store.root)
    manifests = {s: load_manifest(tmp_store.root, s) for s in committed}
    keep = policy.gc_keep(committed, manifests)
    # delta chains flatten: step 3's reused chunks point straight at step 1's
    # payload (not step 2), so GC keeps {3} + its closure {1} and step 2 dies
    assert keep == [1, 3]
    removed = tmp_store.gc(keep)
    assert removed == [2]
    restored, _ = restore_pytree(tmp_store, 3, verify_digests=True)
    assert tree_equal(jax.tree.map(np.asarray, state), restored)


@settings(max_examples=25, deadline=None)
@given(
    shape=st.tuples(st.integers(1, 20), st.integers(1, 20)),
    dtype=st.sampled_from(["float32", "int32", "uint8", "bfloat16", "bool"]),
    chunk_bytes=st.sampled_from([16, 128, 4096]),
    seed=st.integers(0, 2**31),
)
def test_property_roundtrip_any_leaf(tmp_path_factory, shape, dtype, chunk_bytes, seed):
    import ml_dtypes

    tmp = tmp_path_factory.mktemp("prop")
    store = ChunkStore(str(tmp))
    r = np.random.default_rng(seed)
    dt = np.dtype(ml_dtypes.bfloat16) if dtype == "bfloat16" else np.dtype(dtype)
    if dt.kind == "b":
        arr = r.integers(0, 2, shape).astype(bool)
    elif dt.kind in "fV" or dtype == "bfloat16":
        arr = r.standard_normal(shape).astype(np.float32).astype(dt)
    else:
        arr = r.integers(0, 100, shape).astype(dt)
    state = {"leaf": arr, "meta": np.int64(seed)}
    save_pytree(state, store, 7, chunk_bytes=chunk_bytes)
    restored, _ = restore_pytree(store, 7, verify_digests=True)
    assert tree_equal(state, restored)
