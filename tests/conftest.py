import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_store(tmp_path):
    from repro.checkpoint import ChunkStore

    return ChunkStore(str(tmp_path / "ckpt"))
