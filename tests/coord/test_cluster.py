"""Multi-process cluster drills: real worker processes under the
coordinator + restart supervisor. Marked ``integration`` (spawns N OS
processes per test; each imports jax)."""
import json
import os
import signal
import threading

import pytest

from repro.checkpoint.manifest import committed_steps, step_dir
from repro.coord.supervisor import run_cluster

pytestmark = pytest.mark.integration

BACKENDS = ["thread"] + (["fork"] if hasattr(os, "fork") else [])


def _read_log(path):
    with open(path) as f:
        return [json.loads(line) for line in f]


def test_happy_path_two_hosts(tmp_path):
    from repro.obs.leakcheck import LeakCheck

    root = str(tmp_path / "cluster")
    # the launcher must not accrete fds or /dev/shm segments across a
    # full coordinator round-trip (workers are separate processes; their
    # sockets, queues and sentinels all close with the run)
    with LeakCheck(tolerance=4, shm_tolerance=2) as lc:
        report = run_cluster(
            root=root, n_hosts=2, total_steps=4, ckpt_every=2,
            backend="thread", loop="numpy", deadline_s=180.0,
        )
    assert lc.diff()["fd_growth"] <= 4
    assert [r.step for r in report.committed] == [2, 4]
    assert report.aborted == []
    # the watchdog ran the whole time and the happy path is alert-free
    assert report.alerts == []
    assert report.latest_committed == 4
    assert report.lockstep()
    assert committed_steps(root) == [2, 4]
    # every committed step is a fully merged image: MANIFEST + COMMIT +
    # one hostmeta and one payload file per host
    for s in (2, 4):
        names = set(os.listdir(step_dir(root, s)))
        assert {"MANIFEST.msgpack", "COMMIT"} <= names
        assert {"hostmeta-h0000.msgpack", "hostmeta-h0001.msgpack"} <= names
        assert {"data-h0000.bin", "data-h0001.bin"} <= names


def test_kill_and_respawn_converges(tmp_path):
    """The acceptance drill: --hosts 4 --kill-host 2 --kill-at-step 6."""
    root = str(tmp_path / "cluster")
    report = run_cluster(
        root=root, n_hosts=4, total_steps=9, ckpt_every=3,
        backend="thread", loop="numpy", deadline_s=300.0,
        kill_host=2, kill_at_step=6,
    )
    # the killed worker was respawned exactly once and the cluster converged
    assert report.restarts[2] == 1
    assert report.lockstep()
    assert report.latest_committed == 9
    # the round at the kill boundary aborted, then its retry committed
    aborted = [r for r in report.aborted if r.step == 6]
    assert aborted, f"no aborted round at step 6: {report.rounds}"
    assert "host 2" in aborted[0].reason
    assert [r.step for r in report.committed] == [3, 6, 9]
    # the respawned incarnation restored from the last committed step
    joins = [e for e in _read_log(report.log_path)
             if e["event"] == "join" and e["host"] == 2
             and e.get("restored_from") is not None]
    assert joins and joins[-1]["restored_from"] == 3
    # no partial/corrupt commits anywhere
    assert committed_steps(root) == [3, 6, 9]
    # the watchdog saw the death: a worker_death alert was journaled
    # BEFORE the retried round at the kill boundary committed
    assert "worker_death" in report.alert_kinds()
    log = _read_log(report.log_path)
    alert_i = next(i for i, e in enumerate(log)
                   if e["event"] == "alert" and e["kind"] == "worker_death")
    commit6_i = next(i for i, e in enumerate(log)
                     if e["event"] == "round" and e["step"] == 6
                     and e["status"] == "committed")
    assert alert_i < commit6_i


def test_supervisor_respawns_pre_reaped_death(tmp_path):
    """Reap-race regression: a worker that is already dead — and whose exit
    status ``is_alive()`` has already collected via waitpid — before the
    watch loop's first pass must still be respawned. The old loop only
    reaped deaths whose sentinel fired inside its own ``sentinel_wait``
    call, so a death noticed by ``is_alive()`` first was dropped forever
    and the cluster hung at the barrier until the coordinator deadline
    (the order-dependent timeout seen when this file runs sequentially
    under load)."""
    from repro.coord.coordinator import Coordinator
    from repro.coord.supervisor import ClusterSupervisor
    from repro.coord.worker import WorkerConfig

    root = str(tmp_path / "cluster")
    coord = Coordinator(root, n_hosts=1).start()
    host_addr, port = coord.address
    cfg = WorkerConfig(
        host=0, n_hosts=1, coord_host=host_addr, coord_port=port,
        root=root, total_steps=2, ckpt_every=2, backend="thread",
        loop="numpy", deadline_s=120.0,
    )
    sup = ClusterSupervisor([cfg])
    sup.start()
    # kill AND fully reap before watch() runs: no sentinel event is left
    # for the watch loop to observe, only the is_alive() fact
    os.kill(sup.procs[0].pid, signal.SIGKILL)
    sup.procs[0].join()
    assert not sup.procs[0].is_alive()

    coord_err = {}

    def drive():
        try:
            coord.run(deadline_s=120.0)
        except Exception as e:  # surfaced below
            coord_err["e"] = e

    driver = threading.Thread(target=drive, daemon=True)
    driver.start()
    try:
        sup.watch(coord.done, deadline_s=120.0)
    finally:
        sup.terminate()
    driver.join(timeout=30)
    assert "e" not in coord_err, coord_err
    assert sup.restarts[0] == 1
    assert coord.latest_committed == 2


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_mid_commit_aborts_and_restores_previous(tmp_path, backend):
    """Kill a worker after its hostmeta is written but before the ack:
    the round must abort with no MANIFEST/COMMIT, and the respawned worker
    must restore from the *previous* committed step. Over both persist
    backends."""
    root = str(tmp_path / f"cluster-{backend}")
    report = run_cluster(
        root=root, n_hosts=2, total_steps=6, ckpt_every=2,
        backend=backend, loop="numpy", deadline_s=300.0,
        die_after_persist_host=1, die_after_persist_step=4,
        sweep=False,  # keep the aborted round's partial files visible
    )
    # the round at step 4 aborted first, then committed on retry
    step4 = [r for r in report.rounds if r.step == 4]
    assert [r.status for r in step4] == ["aborted", "committed"]
    # mid-commit death: the dying host HAD persisted (hostmeta on disk)
    # yet the decision never appeared until every participant acked
    assert report.restarts[1] == 1
    assert report.lockstep()
    assert report.latest_committed == 6
    assert committed_steps(root) == [2, 4, 6]
    # the respawned worker restored from the previous committed step (2),
    # not from the aborted round's staged image
    joins = [e for e in _read_log(report.log_path)
             if e["event"] == "join" and e["host"] == 1
             and e.get("restored_from") is not None]
    assert joins and joins[-1]["restored_from"] == 2
    # the death event was journaled while step 2 was still the restore target
    deaths = [e for e in _read_log(report.log_path) if e["event"] == "death"]
    assert deaths and deaths[0]["latest_committed"] == 2


def test_proxy_device_runner_lockstep_and_kill(tmp_path):
    """Each worker hosts its own device-proxy process; digests still
    converge, and a killed worker (whose proxy dies with it) respawns,
    restores, re-pushes into a fresh proxy and reconverges."""
    root = str(tmp_path / "cluster")
    report = run_cluster(
        root=root, n_hosts=2, total_steps=6, ckpt_every=2,
        backend="thread", loop="numpy", device_runner="proxy",
        deadline_s=300.0, kill_host=1, kill_at_step=4,
    )
    assert report.restarts[1] == 1
    assert report.lockstep()
    assert report.latest_committed == 6
    # proxied and inline execution are the same math: an inline cluster
    # over the same config lands on the same digest
    inline = run_cluster(
        root=str(tmp_path / "cluster-inline"), n_hosts=2, total_steps=6,
        ckpt_every=2, backend="thread", loop="numpy", deadline_s=300.0,
    )
    assert inline.lockstep()
    assert (set(report.final_digests.values())
            == set(inline.final_digests.values()))


def test_straggler_flagged_but_never_blocks_commit(tmp_path):
    root = str(tmp_path / "cluster")
    report = run_cluster(
        root=root, n_hosts=3, total_steps=4, ckpt_every=2,
        backend="thread", loop="numpy", deadline_s=300.0,
        straggle_host=2, straggle_s=0.6,
    )
    assert report.aborted == []
    assert report.latest_committed == 4
    assert report.lockstep()
    flagged = {h for r in report.committed for h in r.stragglers}
    assert flagged == {2}
    # the slow host inflates round time, not the commit critical section
    assert all(r.round_s >= 0.6 for r in report.committed)
    assert all(r.commit_s < 0.6 for r in report.committed)
    # the watchdog names the slow host, and only as a warning
    straggler_alerts = [a for a in report.alerts if a["kind"] == "straggler"]
    assert straggler_alerts and all(a["host"] == 2 for a in straggler_alerts)
    assert all(a["severity"] == "warning" for a in report.alerts)


def test_sweep_removes_aborted_partials(tmp_path):
    root = str(tmp_path / "cluster")
    report = run_cluster(
        root=root, n_hosts=2, total_steps=4, ckpt_every=2,
        backend="thread", loop="numpy", deadline_s=300.0,
        kill_host=1, kill_at_step=2,
    )
    assert report.lockstep()
    # all remaining step dirs are committed ones (partials swept at the end)
    for name in os.listdir(root):
        d = os.path.join(root, name)
        if name.startswith("step_") and os.path.isdir(d):
            assert os.path.exists(os.path.join(d, "COMMIT"))
