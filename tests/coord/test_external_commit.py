"""External-commit mode + hostmeta merge: the two-phase commit substrate.

Each simulated host persists only its HostShardView slices (either persist
backend); nothing is visible until the coordinator-side merge writes
MANIFEST + COMMIT; the merged image restores bit-identically.
"""
import os

import numpy as np
import pytest

from repro.checkpoint.manifest import (
    committed_steps,
    hostmeta_path,
    list_hostmetas,
    load_hostmeta,
    merge_hostmetas,
    commit_manifest,
    step_dir,
)
from repro.checkpoint.store import ChunkStore
from repro.core.forked import ForkedCheckpointer
from repro.core.restore import RestoreManager
from repro.coord.worker import shard_tree_for_host, state_digest

BACKENDS = ["thread"] + (["fork"] if hasattr(os, "fork") else [])


def _state(seed=0, rows=8, cols=16):
    rng = np.random.default_rng(seed)
    return {
        "device": {
            "w": rng.standard_normal((rows, cols)).astype(np.float32),
            "b": rng.standard_normal((cols,)).astype(np.float32),
        },
        "host": {"step": np.int64(5)},
    }


def _persist_all_hosts(root, state, step, n_hosts, backend, prev_confirm=None):
    cks = []
    for h in range(n_hosts):
        ck = ForkedCheckpointer(
            ChunkStore(root), chunk_bytes=1 << 8, host=h,
            backend=backend, external_commit=True, digest_on_device=False,
        )
        if prev_confirm is not None:
            ck.commit_confirmed(prev_confirm)
        shard = shard_tree_for_host(state, h, n_hosts)
        ck.save_async(step, shard).wait(60)
        cks.append(ck)
    return cks


@pytest.mark.parametrize("backend", BACKENDS)
def test_external_commit_writes_hostmeta_not_commit(tmp_path, backend):
    root = str(tmp_path / "ck")
    state = _state()
    cks = _persist_all_hosts(root, state, 5, 2, backend)
    d = step_dir(root, 5)
    # staged, not committed: hostmetas + payloads only
    assert sorted(list_hostmetas(root, 5)) == [0, 1]
    assert not os.path.exists(os.path.join(d, "COMMIT"))
    assert not os.path.exists(os.path.join(d, "MANIFEST.msgpack"))
    assert committed_steps(root) == []
    # each hostmeta holds only its host's shards, global shapes throughout
    hm0 = load_hostmeta(root, 5, 0)
    assert hm0.leaves["device/w"].shape == [8, 16]
    (s0,) = hm0.leaves["device/w"].shards
    assert (s0.start, s0.stop) == ([0, 0], [4, 16])
    for ck in cks:
        ck.close()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_hosts", [1, 2, 3])
def test_merge_commit_restore_roundtrip(tmp_path, backend, n_hosts):
    root = str(tmp_path / "ck")
    state = _state(rows=9)  # uneven split across 3 hosts
    cks = _persist_all_hosts(root, state, 5, n_hosts, backend)
    manifest = merge_hostmetas(root, 5)
    commit_manifest(root, manifest)
    assert committed_steps(root) == [5]

    restored, m = RestoreManager(ChunkStore(root)).restore()
    assert m.step == 5
    np.testing.assert_array_equal(restored["device"]["w"], state["device"]["w"])
    np.testing.assert_array_equal(restored["device"]["b"], state["device"]["b"])
    assert int(restored["host"]["step"]) == 5
    assert state_digest(restored) == state_digest(state)
    # merged meta reports cluster-wide totals, not one host's identity
    assert "host" not in m.meta
    assert sorted(m.meta["hosts"]) == list(range(n_hosts))
    assert m.meta["chunks_written"] == sum(
        v["chunks_written"] for v in m.meta["hosts"].values()
    )
    assert m.meta["chunks_written"] > 0
    for ck in cks:
        ck.close()


def test_merge_rejects_shape_disagreement(tmp_path):
    root = str(tmp_path / "ck")
    a, b = _state(rows=8), _state(rows=12)
    ck0 = _persist_all_hosts(root, a, 1, 2, "thread")[0]
    # host 1 checkpoints a different-shaped state: merging must refuse
    ck1 = ForkedCheckpointer(
        ChunkStore(root), chunk_bytes=1 << 8, host=1,
        backend="thread", external_commit=True, digest_on_device=False,
    )
    ck1.save_async(1, shard_tree_for_host(b, 1, 2)).wait(60)
    with pytest.raises(ValueError, match="disagrees"):
        merge_hostmetas(root, 1)
    ck0.close()
    ck1.close()


def test_merge_missing_hostmetas_raises(tmp_path):
    root = str(tmp_path / "ck")
    os.makedirs(root)
    with pytest.raises(FileNotFoundError):
        merge_hostmetas(root, 7)


@pytest.mark.parametrize("backend", BACKENDS)
def test_confirmed_commit_enables_delta_but_abort_does_not(tmp_path, backend):
    """Incremental deltas may only base on cluster-committed rounds."""
    root = str(tmp_path / "ck")
    state = _state()
    ck = ForkedCheckpointer(
        ChunkStore(root), chunk_bytes=1 << 8, host=0, backend=backend,
        external_commit=True, incremental=True, digest_on_device=False,
    )
    shard = shard_tree_for_host(state, 0, 1)

    r1 = ck.save_async(1, shard)
    r1.wait(60)
    assert r1.chunks_reused == 0
    # round aborted: staged manifest must NOT become the delta base
    ck.commit_aborted(1)
    r2 = ck.save_async(2, shard)
    r2.wait(60)
    assert r2.chunks_reused == 0

    # round committed: now identical chunks are reused as delta references
    commit_manifest(root, merge_hostmetas(root, 2))
    ck.commit_confirmed(2)
    r3 = ck.save_async(3, shard)
    r3.wait(60)
    assert r3.chunks_reused > 0
    assert r3.chunks_written == 0
    ck.close()


def test_unowned_leaf_persists_nothing_but_merges_whole(tmp_path):
    """Scalar/small leaves are whole-owned by one host; the merge still
    reconstructs the full tree for every restore target."""
    root = str(tmp_path / "ck")
    state = {"w": np.arange(8, dtype=np.float32), "s": np.float32(3.5)}
    cks = _persist_all_hosts(root, state, 2, 2, "thread")
    # exactly one hostmeta carries the scalar
    carriers = [
        h for h in (0, 1)
        if load_hostmeta(root, 2, h).leaves["s"].shards
    ]
    assert len(carriers) == 1
    commit_manifest(root, merge_hostmetas(root, 2))
    restored, _ = RestoreManager(ChunkStore(root)).restore()
    np.testing.assert_array_equal(restored["w"], state["w"])
    assert float(restored["s"]) == 3.5
    for ck in cks:
        ck.close()


def test_hostmeta_path_layout(tmp_path):
    assert hostmeta_path(str(tmp_path), 42, 7).endswith(
        os.path.join("step_00000042", "hostmeta-h0007.msgpack")
    )
