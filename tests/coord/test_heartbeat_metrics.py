"""HEARTBEAT metrics piggyback vs a live Coordinator: torn frames,
oversized frames, idempotent redelivery, end-to-end ingestion."""
import queue
import socket
import struct
import threading
import time

import pytest

from repro.coord import protocol
from repro.coord.coordinator import Coordinator


@pytest.fixture
def coord(tmp_path):
    c = Coordinator(str(tmp_path / "root"), n_hosts=1).start()
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            try:
                kind, conn, frame = c._inbox.get(timeout=0.05)
            except queue.Empty:
                continue
            if kind == "eof":
                c._on_eof(conn)
            else:
                c._dispatch(conn, frame)

    t = threading.Thread(target=pump, daemon=True)
    t.start()
    try:
        yield c
    finally:
        stop.set()
        t.join(timeout=5)
        c.close()


def _join(coord, host=0):
    conn = protocol.connect(coord.address, timeout=5)
    conn.settimeout(5)
    conn.send(protocol.MSG_JOIN, host=host, pid=1234, restored_from=None)
    welcome = conn.recv()
    assert welcome["type"] == protocol.MSG_WELCOME
    return conn


def _wait(cond, timeout=5.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if cond():
            return True
        time.sleep(0.01)
    return False


def _beat(conn, host, step, payload):
    conn.send(protocol.MSG_HEARTBEAT, host=host, step=step,
              metrics=payload)


def test_piggyback_lands_in_store_end_to_end(coord):
    conn = _join(coord)
    _beat(conn, 0, 1, {"seq": 1, "counters": {"proxy_syncs_total": 2},
                       "gauges": {"uvm_faults": 7}})
    assert _wait(lambda: coord.live.store.latest(0, "proxy_syncs_total")
                 == 2.0)
    assert coord.live.store.latest(0, "uvm_faults") == 7.0
    # second delta accumulates into the running total
    _beat(conn, 0, 2, {"seq": 2, "counters": {"proxy_syncs_total": 3},
                       "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "proxy_syncs_total")
                 == 5.0)
    conn.close()


def test_redelivered_delta_is_idempotent(coord):
    """The retry path: one delta delivered twice must count once."""
    conn = _join(coord)
    payload = {"seq": 1, "counters": {"x": 5}, "gauges": {}}
    _beat(conn, 0, 1, payload)
    _beat(conn, 0, 1, payload)  # redelivery (same seq, same content)
    assert _wait(lambda: coord.live.store.latest(0, "x") == 5.0)
    time.sleep(0.1)  # let the duplicate drain through the pump
    assert coord.live.store.latest(0, "x") == 5.0
    assert len(coord.live.store.series(0, "x")) == 1
    assert coord.live.dropped >= 1
    conn.close()


def test_rejoin_resets_seq_tracking(coord):
    conn = _join(coord)
    _beat(conn, 0, 1, {"seq": 7, "counters": {"x": 5}, "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "x") == 5.0)
    conn.close()
    # a respawned incarnation starts its piggyback back at seq 1
    conn2 = _join(coord)
    _beat(conn2, 0, 1, {"seq": 1, "counters": {"x": 2}, "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "x") == 2.0)
    conn2.close()


def test_torn_frame_is_eof_not_poison(coord):
    """A worker SIGKILLed mid-send leaves a partial frame; the
    length-prefixed reader turns it into EOF, never a parsed frame."""
    good = _join(coord)
    _beat(good, 0, 1, {"seq": 1, "counters": {"a": 1}, "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "a") == 1.0)

    raw = socket.create_connection(coord.address, timeout=5)
    raw.sendall(struct.pack("<I", 100) + b"\x93\x01")  # 100 promised, 2 sent
    raw.close()

    # the coordinator shrugged: the good connection still ingests
    _beat(good, 0, 2, {"seq": 2, "counters": {"a": 1}, "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "a") == 2.0)
    assert coord.live.ingested == 2
    good.close()


def test_oversized_frame_is_rejected_not_buffered(coord):
    """A corrupt/hostile length header must not make the coordinator
    allocate or stall — the reader raises and the connection dies."""
    good = _join(coord)
    raw = socket.create_connection(coord.address, timeout=5)
    raw.sendall(struct.pack("<I", protocol.MAX_FRAME + 1) + b"x" * 64)
    # reader thread hits ValueError -> eof; peer sees the close
    raw.settimeout(5)
    assert raw.recv(1) == b""  # coordinator closed it
    raw.close()

    _beat(good, 0, 1, {"seq": 1, "counters": {"b": 3}, "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "b") == 3.0)
    good.close()


def test_garbage_metrics_payload_never_kills_dispatch(coord):
    conn = _join(coord)
    for step, payload in enumerate(
        ("nonsense", {"seq": "x"}, {"seq": -1}, [1, 2], 9.5), start=1
    ):
        _beat(conn, 0, step, payload)
    _beat(conn, 0, 9, {"seq": 1, "counters": {"ok": 1}, "gauges": {}})
    assert _wait(lambda: coord.live.store.latest(0, "ok") == 1.0)
    assert coord.live.dropped >= 4
    conn.close()


def test_heartbeat_without_metrics_still_beats(coord):
    """Bare heartbeats (nothing new to report) stay valid liveness."""
    conn = _join(coord)
    conn.send(protocol.MSG_HEARTBEAT, host=0, step=3)
    _beat(conn, 0, 4, {"seq": 1, "counters": {}, "gauges": {}})
    assert _wait(lambda: coord.live.ingested == 1)
    assert 0 not in coord.monitor.dead_hosts()
    conn.close()
