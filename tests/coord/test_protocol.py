"""Wire protocol: framing, EOF semantics, concurrent sends."""
import socket
import threading

import pytest

from repro.coord.protocol import (
    MSG_HEARTBEAT,
    Connection,
    recv_frame,
    send_frame,
)


def _pair():
    a, b = socket.socketpair()
    return a, b


def test_frame_roundtrip():
    a, b = _pair()
    msg = {"type": "JOIN", "host": 3, "pid": 123, "restored_from": None,
           "blob": b"\x00\xff", "f": 1.5}
    send_frame(a, msg)
    out = recv_frame(b)
    assert out == msg
    a.close()
    b.close()


def test_multiple_frames_in_order():
    a, b = _pair()
    for i in range(10):
        send_frame(a, {"i": i})
    got = [recv_frame(b)["i"] for _ in range(10)]
    assert got == list(range(10))
    a.close()
    b.close()


def test_eof_returns_none():
    a, b = _pair()
    send_frame(a, {"x": 1})
    a.close()
    assert recv_frame(b) == {"x": 1}
    assert recv_frame(b) is None  # clean EOF, not an exception
    b.close()


def test_truncated_frame_is_eof():
    a, b = _pair()
    import struct

    a.sendall(struct.pack("<I", 100) + b"short")  # dies mid-message
    a.close()
    assert recv_frame(b) is None
    b.close()


def test_corrupt_length_header_raises():
    a, b = _pair()
    import struct

    a.sendall(struct.pack("<I", 1 << 30))
    with pytest.raises(ValueError):
        recv_frame(b)
    a.close()
    b.close()


def test_connection_recv_keeps_progress_across_timeouts():
    """A frame whose bytes straddle a socket timeout must not be torn:
    workers poll with short timeouts and a half-read header would desync
    the framed stream."""
    import struct

    import msgpack

    a, b = _pair()
    b.settimeout(0.05)
    conn = Connection(b)
    payload = msgpack.packb({"type": "DRAIN", "step": 6}, use_bin_type=True)
    # drip-feed: header alone, then partial payload, then the rest
    a.sendall(struct.pack("<I", len(payload)))
    with pytest.raises((TimeoutError, socket.timeout)):
        conn.recv()
    a.sendall(payload[:3])
    with pytest.raises((TimeoutError, socket.timeout)):
        conn.recv()
    a.sendall(payload[3:])
    assert conn.recv() == {"type": "DRAIN", "step": 6}
    # the stream is still in sync for the next frame
    send_frame(a, {"type": "COMMIT", "step": 6})
    assert conn.recv() == {"type": "COMMIT", "step": 6}
    a.close()
    b.close()


def test_connection_concurrent_sends_do_not_interleave():
    a, b = _pair()
    conn = Connection(a)
    n_threads, per_thread = 4, 25

    def sender(tid):
        for i in range(per_thread):
            conn.send(MSG_HEARTBEAT, host=tid, step=i)

    threads = [threading.Thread(target=sender, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    seen = {}
    for _ in range(n_threads * per_thread):
        msg = recv_frame(b)
        assert msg["type"] == MSG_HEARTBEAT
        # per-sender messages must arrive whole and in per-thread order
        last = seen.get(msg["host"], -1)
        assert msg["step"] == last + 1
        seen[msg["host"]] = msg["step"]
    conn.close()
    b.close()
