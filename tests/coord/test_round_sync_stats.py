"""Per-round incremental sync stats in CLUSTER_LOG.jsonl round records."""
import json

import pytest

from repro.coord.supervisor import run_cluster

pytestmark = pytest.mark.integration


def _round_records(log_path):
    with open(log_path) as f:
        return [json.loads(line) for line in f
                if json.loads(line).get("event") == "round"]


def test_round_records_carry_incremental_sync_stats(tmp_path):
    root = str(tmp_path / "cluster")
    report = run_cluster(
        root=root, n_hosts=2, total_steps=4, ckpt_every=2,
        backend="thread", loop="numpy", deadline_s=180.0,
    )
    assert [r.step for r in report.committed] == [2, 4]
    rounds = _round_records(report.log_path)
    committed = [r for r in rounds if r["status"] == "committed"]
    assert len(committed) == 2
    for rec in committed:
        # the new fields are present and aggregated over both hosts
        assert {"chunks_synced", "chunks_clean", "bytes_skipped"} <= set(rec)
        assert rec["chunks_synced"] > 0  # something moved each round
    first, second = committed
    # round 2's sync diffs against round 1's shadow: with a numpy_sgd
    # state where every chunk changes each step the clean count may be 0,
    # but the accounting identity must hold per round
    for rec in committed:
        assert rec["chunks_synced"] >= 0 and rec["chunks_clean"] >= 0
        assert rec["bytes_skipped"] >= 0
    # in-memory RoundRecord mirrors the journal
    assert report.committed[0].chunks_synced == first["chunks_synced"]
    assert report.committed[1].bytes_skipped == second["bytes_skipped"]
