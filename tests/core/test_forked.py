"""ForkedCheckpointer: async two-phase save, blocking-time economics,
incremental deltas, pipelining, failure surfacing — over both persist
backends (writer-pool ``thread`` and true-COW ``fork``)."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ChunkStore, latest_committed_step
from repro.checkpoint.codecs import Codec, register_codec, unregister_codec
from repro.core import CheckpointPolicy, ForkedCheckpointer, RestoreManager
from repro.utils.tree import tree_equal

BACKENDS = ["thread"] + (["fork"] if hasattr(os, "fork") else [])


@pytest.fixture(params=BACKENDS)
def backend(request):
    return request.param


def _state(step=1, n=1 << 16):
    return {
        "device": {"w": jnp.arange(n, dtype=jnp.float32) + step},
        "host": {"step": np.int64(step)},
    }


def test_async_save_restores_exactly(tmp_store, backend):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096, backend=backend)
    s = _state(1)
    r = ck.save_async(1, s)
    r.wait()
    assert r.error is None
    restored, m = RestoreManager(tmp_store).restore(verify=True)
    assert tree_equal(jax.tree.map(np.asarray, s), restored)
    ck.close()


def test_blocking_time_less_than_total(tmp_store, backend):
    """The paper's headline: application blocks only for phase 1."""
    ck = ForkedCheckpointer(
        tmp_store, chunk_bytes=1 << 14, codec="gzip", backend=backend
    )
    s = _state(1, n=1 << 20)  # 4 MB
    r = ck.save_async(1, s)
    r.wait()
    assert r.blocking_s < r.blocking_s + r.persist_s
    assert r.persist_s > 0
    ck.close()


def test_incremental_second_save_writes_less(tmp_store, backend):
    ck = ForkedCheckpointer(
        tmp_store, chunk_bytes=4096, incremental=True, backend=backend
    )
    s = _state(1)
    ck.save_async(1, s).wait()
    s2 = {
        "device": {"w": s["device"]["w"].at[0].set(-1.0)},
        "host": {"step": np.int64(2)},
    }
    r2 = ck.save_async(2, s2)
    r2.wait()
    assert r2.chunks_reused > 0
    assert r2.chunks_written <= 3  # 1 dirty chunk + host step leaf
    restored, _ = RestoreManager(tmp_store).restore(verify=True)
    assert tree_equal(jax.tree.map(np.asarray, s2), restored)
    ck.close()


def test_pipeline_bounded_by_max_pending(tmp_store, backend):
    ck = ForkedCheckpointer(
        tmp_store, chunk_bytes=4096, max_pending=1, backend=backend
    )
    for step in range(1, 5):
        ck.save_async(step, _state(step))
    done = ck.wait_all()
    assert all(r.error is None for r in done)
    assert latest_committed_step(tmp_store.root) == 4
    ck.close()


def test_save_sync_includes_persist_in_blocking(tmp_store, backend):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096, backend=backend)
    r = ck.save_sync(1, _state(1))
    assert r.blocking_s >= r.persist_s
    ck.close()


def test_persist_failure_surfaces_at_wait(tmp_store, backend):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096, backend=backend)
    # sabotage the store root after construction
    import shutil

    r = ck.save_async(1, _state(1))
    r.wait()  # first one fine
    shutil.rmtree(tmp_store.root)
    # make root un-creatable by placing a file where the dir should be
    with open(tmp_store.root, "w") as f:
        f.write("not a dir")
    r2 = ck.save_async(2, _state(2))
    with pytest.raises(RuntimeError, match="failed"):
        r2.wait(timeout=60)
    ck.close()  # close() drains without re-raising


@pytest.fixture
def crash_codecs():
    """Sabotage codecs, registered only for the duration of a test so the
    global registry (which test_roundtrip parametrizes over) stays clean."""
    register_codec(Codec(
        "boom-raise",
        lambda b: (_ for _ in ()).throw(RuntimeError("codec exploded")),
        lambda b: b,
    ), replace=True)
    register_codec(Codec("boom-exit", lambda b: os._exit(3), lambda b: b),
                   replace=True)
    yield
    unregister_codec("boom-raise")
    unregister_codec("boom-exit")


def test_failing_codec_surfaces_as_error_not_hang(tmp_store, backend, crash_codecs):
    """A crash inside phase 2 (here: the codec) must surface at wait()."""
    ck = ForkedCheckpointer(tmp_store, codec="boom-raise", backend=backend)
    r = ck.save_async(1, _state(1))
    with pytest.raises(RuntimeError, match="codec exploded"):
        r.wait(timeout=60)
    assert r.error is not None
    ck.close()


@pytest.mark.skipif(not hasattr(os, "fork"), reason="needs os.fork")
def test_fork_child_hard_crash_surfaces_as_error_not_hang(tmp_store, crash_codecs):
    """A child that dies without reporting (os._exit mid-persist) must be
    reaped and converted into CheckpointResult.error, not a hang."""
    ck = ForkedCheckpointer(tmp_store, codec="boom-exit", backend="fork")
    r = ck.save_async(1, _state(1))
    with pytest.raises(RuntimeError, match="exit"):
        r.wait(timeout=60)
    assert "3" in r.error
    ck.close()


@pytest.mark.skipif(not hasattr(os, "fork"), reason="needs os.fork")
def test_fork_backend_limits_live_children(tmp_store):
    """max_pending bounds concurrent forked children (paper: one at a time)."""
    ck = ForkedCheckpointer(
        tmp_store, chunk_bytes=4096, max_pending=1, backend="fork"
    )
    peak = 0
    for step in range(1, 5):
        ck.save_async(step, _state(step))
        peak = max(peak, len(ck.backend._live))
    ck.wait_all()
    assert peak <= 1
    ck.close()


def test_concurrent_buffer_acquisition_no_lost_wakeup(tmp_store, backend):
    """Regression: the old busy-event scan let two waiters spin-race for the
    buffer released by the oldest pending checkpoint. Hammer save_async from
    several threads; every save must complete and commit."""
    ck = ForkedCheckpointer(
        tmp_store, chunk_bytes=4096, max_pending=1, backend=backend
    )
    errs = []

    def saver(base):
        try:
            for i in range(3):
                ck.save_async(base + i, _state(base + i)).wait(timeout=120)
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=saver, args=(100 * t,)) for t in (1, 2, 3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert latest_committed_step(tmp_store.root) is not None
    ck.close()


def test_policy_cadence_and_preempt():
    p = CheckpointPolicy(interval_steps=10)
    assert not p.should_checkpoint(5)
    assert p.should_checkpoint(10)
    p.notify_checkpointed(10)
    assert not p.should_checkpoint(11)
    p.request_preempt_checkpoint()
    assert p.should_checkpoint(11)
