"""ForkedCheckpointer: async two-phase save, blocking-time economics,
incremental deltas, pipelining, failure surfacing."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ChunkStore, latest_committed_step
from repro.core import CheckpointPolicy, ForkedCheckpointer, RestoreManager
from repro.utils.tree import tree_equal


def _state(step=1, n=1 << 16):
    return {
        "device": {"w": jnp.arange(n, dtype=jnp.float32) + step},
        "host": {"step": np.int64(step)},
    }


def test_async_save_restores_exactly(tmp_store):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096)
    s = _state(1)
    r = ck.save_async(1, s)
    r.wait()
    assert r.error is None
    restored, m = RestoreManager(tmp_store).restore(verify=True)
    assert tree_equal(jax.tree.map(np.asarray, s), restored)
    ck.close()


def test_blocking_time_less_than_total(tmp_store):
    """The paper's headline: application blocks only for phase 1."""
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=1 << 14, codec="gzip")
    s = _state(1, n=1 << 20)  # 4 MB
    r = ck.save_async(1, s)
    r.wait()
    assert r.blocking_s < r.blocking_s + r.persist_s
    assert r.persist_s > 0
    ck.close()


def test_incremental_second_save_writes_less(tmp_store):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096, incremental=True)
    s = _state(1)
    ck.save_async(1, s).wait()
    s2 = {
        "device": {"w": s["device"]["w"].at[0].set(-1.0)},
        "host": {"step": np.int64(2)},
    }
    r2 = ck.save_async(2, s2)
    r2.wait()
    assert r2.chunks_reused > 0
    assert r2.chunks_written <= 3  # 1 dirty chunk + host step leaf
    restored, _ = RestoreManager(tmp_store).restore(verify=True)
    assert tree_equal(jax.tree.map(np.asarray, s2), restored)
    ck.close()


def test_pipeline_bounded_by_max_pending(tmp_store):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096, max_pending=1)
    for step in range(1, 5):
        ck.save_async(step, _state(step))
    done = ck.wait_all()
    assert all(r.error is None for r in done)
    assert latest_committed_step(tmp_store.root) == 4
    ck.close()


def test_save_sync_includes_persist_in_blocking(tmp_store):
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=4096)
    r = ck.save_sync(1, _state(1))
    assert r.blocking_s >= r.persist_s
    ck.close()


def test_persist_failure_surfaces_at_wait(tmp_store):
    ck = ForkedCheckpointer(tmp_store, codec="zstd1", chunk_bytes=4096)
    # sabotage the store root after construction
    import shutil

    r = ck.save_async(1, _state(1))
    r.wait()  # first one fine
    shutil.rmtree(tmp_store.root)
    # make root un-creatable by placing a file where the dir should be
    with open(tmp_store.root, "w") as f:
        f.write("not a dir")
    r2 = ck.save_async(2, _state(2))
    with pytest.raises(RuntimeError, match="failed"):
        r2.wait()
    ck._pool.shutdown(wait=False)


def test_policy_cadence_and_preempt():
    p = CheckpointPolicy(interval_steps=10)
    assert not p.should_checkpoint(5)
    assert p.should_checkpoint(10)
    p.notify_checkpointed(10)
    assert not p.should_checkpoint(11)
    p.request_preempt_checkpoint()
    assert p.should_checkpoint(11)
