"""LazyLeaves exponential read-ahead: window growth, clamp, and concurrent
first-access materialization (satellite for core/restore.py)."""
import threading

import numpy as np

from repro.checkpoint import save_pytree
from repro.core import RestoreManager


def _big_state(n_leaves=16):
    import jax.numpy as jnp

    return {f"p{i:02d}": jnp.full((256,), i, jnp.float32) for i in range(n_leaves)}


def test_window_grows_exponentially_1_2_4(tmp_store):
    save_pytree(_big_state(32), tmp_store, 1)
    lazy, _ = RestoreManager(tmp_store).restore(lazy=True)
    assert lazy._window == 1          # paper: first fault reads one page
    keys = lazy.keys()
    observed = []
    for k in keys[:4]:
        lazy[k]
        observed.append(lazy._window)
    assert observed == [2, 4, 8, 16]  # doubles on each forward access
    lazy.close()


def test_window_clamped_at_max_readahead(tmp_store):
    from repro.checkpoint.manifest import load_manifest

    save_pytree(_big_state(32), tmp_store, 1)
    store = tmp_store
    manifest = load_manifest(store.root, 1)
    from repro.core.restore import LazyLeaves

    lazy = LazyLeaves(store, manifest, None, max_readahead=4)
    for k in lazy.keys()[:8]:
        lazy[k]
        assert lazy._window <= 4
    assert lazy._window == 4
    lazy.close()


def test_backward_jump_resets_then_regrows(tmp_store):
    save_pytree(_big_state(32), tmp_store, 1)
    lazy, _ = RestoreManager(tmp_store).restore(lazy=True)
    keys = lazy.keys()
    lazy[keys[10]]
    lazy[keys[11]]
    assert lazy._window == 4
    lazy[keys[2]]                 # backward jump: new region
    assert lazy._window == 1
    lazy[keys[3]]
    assert lazy._window == 2      # regrows from the reset stride
    lazy.close()


def test_concurrent_first_access_materializes_once(tmp_store):
    save_pytree(_big_state(4), tmp_store, 1)
    lazy, _ = RestoreManager(tmp_store).restore(lazy=True)
    path = lazy.keys()[0]
    results, errs = [], []
    barrier = threading.Barrier(8)

    def hit():
        try:
            barrier.wait(timeout=10)
            results.append(lazy[path])
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=hit) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(results) == 8
    # one materialization, one identical object for everyone
    assert all(r is results[0] for r in results)
    first = np.asarray(results[0])
    assert np.array_equal(first, np.full((256,), 0, np.float32))
    # direct loads + prefetch loads never exceed one per leaf
    assert lazy.loads <= len(lazy.keys())
    lazy.close()
