"""Lazy restore read-ahead + failure detection/straggler machinery."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ChunkStore, save_pytree
from repro.core import (
    HeartbeatMonitor,
    LazyLeaves,
    RestoreManager,
    StragglerPolicy,
)
from repro.utils.tree import tree_equal


def _big_state(n_leaves=12):
    return {f"p{i:02d}": jnp.full((256,), i, jnp.float32) for i in range(n_leaves)}


def test_lazy_restore_returns_correct_leaves(tmp_store):
    s = _big_state()
    save_pytree(s, tmp_store, 1)
    lazy, _ = RestoreManager(tmp_store).restore(lazy=True)
    assert np.array_equal(np.asarray(lazy["p03"]), np.full((256,), 3, np.float32))
    assert tree_equal(jax.tree.map(np.asarray, s), lazy.as_tree())
    lazy.close()


def test_lazy_readahead_window_grows(tmp_store):
    s = _big_state(16)
    save_pytree(s, tmp_store, 1)
    lazy, _ = RestoreManager(tmp_store).restore(lazy=True)
    keys = lazy.keys()
    lazy[keys[0]]
    w1 = lazy._window
    lazy[keys[1]]
    w2 = lazy._window
    assert w2 >= w1  # sequential access grows the window (exp read-ahead)
    # backward jump to an *uncached* leaf resets the stride
    lazy2, _ = RestoreManager(tmp_store).restore(lazy=True)
    lazy2[lazy2.keys()[8]]
    assert lazy2._window > 1
    lazy2[lazy2.keys()[2]]
    assert lazy2._window == 1
    lazy.close()
    lazy2.close()


def test_lazy_prefetch_reduces_sync_loads(tmp_store):
    s = _big_state(16)
    save_pytree(s, tmp_store, 1)
    lazy, _ = RestoreManager(tmp_store).restore(lazy=True)
    for k in lazy.keys():
        lazy[k]
        time.sleep(0.01)  # let prefetchers land
    # every leaf was loaded exactly once (cache + futures dedupe)
    assert lazy.loads <= len(lazy.keys()) + 2
    lazy.close()


def test_heartbeat_detects_dead_host():
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=0.05)
    mon.beat(0)
    mon.beat(1)
    time.sleep(0.08)
    mon.beat(1)
    dead = mon.dead_hosts()
    assert 2 in dead and 0 in dead and 1 not in dead
    assert not mon.all_alive()


def test_straggler_flag_and_rebalance():
    sp = StragglerPolicy(multiplier=3.0, min_samples=3)
    for h, t in [(0, 1.0), (1, 1.1), (2, 0.9), (3, 10.0)]:
        sp.record(h, t)
    assert sp.stragglers() == [3]
    assignments = {0: ["a"], 1: ["b"], 2: ["c"], 3: ["d", "e"]}
    out = sp.rebalance(assignments, buddies={3: 0})
    assert out[3] == [] and set(out[0]) == {"a", "d", "e"}


def test_straggler_needs_min_samples():
    sp = StragglerPolicy(min_samples=5)
    sp.record(0, 100.0)
    sp.record(1, 0.1)
    assert sp.stragglers() == []
