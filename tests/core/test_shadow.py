"""ShadowStateManager: Algorithm-1 FSM behaviour + digest-gated fetches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.testing import given, settings, st

from repro.core import ChunkState, ShadowStateManager


def _state(n=4096):
    return {"w": jnp.arange(n, dtype=jnp.float32), "b": jnp.ones((16,), jnp.float32)}


def test_first_sync_fetches_everything():
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    st1 = sh.sync(s)
    assert st1.chunks_fetched == st1.chunks_total


def test_clean_sync_fetches_nothing():
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    sh.mark_device_step()
    st2 = sh.sync(s)
    assert st2.chunks_fetched == 0
    # and all chunks are CLEAN afterwards
    for states in sh.chunk_states().values():
        assert all(c is ChunkState.CLEAN for c in states)


def test_single_element_change_fetches_one_chunk():
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    s2 = dict(s)
    s2["w"] = s["w"].at[300].set(-1.0)  # chunk 1 of w (256 f32 per chunk)
    sh.mark_device_step()
    st3 = sh.sync(s2)
    assert st3.chunks_fetched == 1
    # shadow content matches the new device state
    snap = sh.snapshot()
    w_bytes = snap[("w", 0)]["data"]
    w_restored = w_bytes.view(np.float32)
    assert np.array_equal(w_restored, np.asarray(s2["w"]))


def test_without_mark_no_refetch_even_if_changed():
    """FSM honesty: CLEAN chunks are trusted (the paper's protocol requires
    the device-step event to invalidate)."""
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    s2 = dict(s)
    s2["w"] = s["w"].at[0].set(123.0)
    st2 = sh.sync(s2)  # no mark_device_step
    assert st2.chunks_fetched == 0


def test_invalidate_forces_full_resync():
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    sh.invalidate()
    st2 = sh.sync(s)
    assert st2.chunks_fetched == st2.chunks_total


def test_digest_on_device_and_host_agree():
    s = _state()
    a = ShadowStateManager(chunk_bytes=512, digest_on_device=True)
    b = ShadowStateManager(chunk_bytes=512, digest_on_device=False)
    a.register(s), b.register(s)
    a.sync(s), b.sync(s)
    da = {k: v.digests for k, v in a._streams.items()}
    db = {k: v.digests for k, v in b._streams.items()}
    assert da == db


@settings(max_examples=20, deadline=None)
@given(
    edits=st.lists(st.integers(0, 4095), min_size=0, max_size=8),
    chunk=st.sampled_from([256, 1024]),
)
def test_property_fetched_chunks_exactly_cover_edits(edits, chunk):
    """Fetch set == union of chunks containing an edited element."""
    s = _state()
    sh = ShadowStateManager(chunk_bytes=chunk)
    sh.register(s)
    sh.sync(s)
    w = s["w"]
    for i in edits:
        w = w.at[i].set(w[i] + 1.0)
    s2 = dict(s)
    s2["w"] = w
    sh.mark_device_step()
    stats = sh.sync(s2)
    per_chunk_elems = chunk // 4
    expected = {i // per_chunk_elems for i in edits}
    assert stats.chunks_fetched == len(expected)
