"""Precise (page-granular) dirty marks on the ShadowStateManager."""
import numpy as np

from repro.core import ChunkState, ShadowStateManager


def _state(n=4096):
    return {"w": np.arange(n, dtype=np.float32),
            "h": np.ones(64, np.float32)}


def _synced_shadow(s, chunk_bytes=1024):
    sh = ShadowStateManager(chunk_bytes=chunk_bytes, digest_on_device=False)
    sh.register(s)
    sh.sync(s)
    return sh


def test_precise_marks_fetch_exactly_marked_chunks():
    s = _state()
    sh = _synced_shadow(s)
    s2 = dict(s)
    w = np.array(s["w"])
    w[300] = -1.0   # chunk 1
    w[2000] = -2.0  # chunk 7
    s2["w"] = w
    sh.mark_device_step({"w": [1, 7], "h": []})
    stats = sh.sync(s2)
    # exactly the marked chunks moved — and NO digest pass decided that
    assert stats.chunks_fetched == 2
    snap = sh.snapshot()
    assert np.array_equal(snap[("w", 0)]["data"].view(np.float32), w)


def test_precise_marks_trusted_unmarked_changes_skipped():
    """Trust contract: precise marks are authoritative. An unmarked change
    is NOT fetched (the page table would have marked it)."""
    s = _state()
    sh = _synced_shadow(s)
    s2 = dict(s)
    w = np.array(s["w"])
    w[300] = -1.0  # chunk 1, deliberately NOT marked
    s2["w"] = w
    sh.mark_device_step({"w": [], "h": []})
    stats = sh.sync(s2)
    assert stats.chunks_fetched == 0


def test_unlisted_paths_stay_conservative():
    """Paths outside the marks dict get the full digest-gated treatment."""
    s = _state()
    sh = _synced_shadow(s)
    s2 = dict(s)
    s2["h"] = s["h"] * 3.0  # changed, but 'h' is not in the marks dict
    sh.mark_device_step({"w": []})
    stats = sh.sync(s2)
    assert stats.chunks_fetched == 1  # h's single chunk, found via digest
    snap = sh.snapshot()
    assert np.array_equal(snap[("h", 0)]["data"].view(np.float32), s2["h"])


def test_precise_sync_maintains_digests_for_later_digest_sync():
    """A precise sync must leave correct digests behind so a later
    conservative sync's digest compare still works."""
    s = _state()
    sh = _synced_shadow(s)
    s2 = dict(s)
    w = np.array(s["w"]); w[0] = -5.0
    s2["w"] = w
    sh.mark_device_step({"w": [0], "h": []})
    sh.sync(s2)
    # now a conservative pass over an UNchanged state fetches nothing —
    # only possible if the precise pass updated chunk 0's digest
    sh.mark_device_step()
    stats = sh.sync(s2)
    assert stats.chunks_fetched == 0


def test_precise_full_mark_bulk_path():
    s = _state()
    sh = _synced_shadow(s)
    s2 = dict(s)
    s2["w"] = np.array(s["w"]) + 1.0
    n_chunks = len(sh.chunk_states()[("w", 0)])
    sh.mark_device_step({"w": list(range(n_chunks)), "h": []})
    stats = sh.sync(s2)
    assert stats.chunks_fetched == n_chunks
    sh.mark_device_step()
    assert sh.sync(s2).chunks_fetched == 0  # digests correct after bulk


def test_mark_host_chunks_partial_upload():
    s = _state()
    sh = _synced_shadow(s)
    # mutate two chunks of the shadow buffer, mark only those
    snap = sh.snapshot()
    buf = snap[("w", 0)]["data"]
    buf[0:4] = 255
    buf[1024:1028] = 255
    sh.mark_host_chunks("w", [0, 1])
    states = sh.chunk_states()[("w", 0)]
    assert states[0] is ChunkState.HOST_DIRTY
    assert states[1] is ChunkState.HOST_DIRTY
    assert all(c is ChunkState.CLEAN for c in states[2:])
    new_state, stats = sh.upload(s)
    assert stats.chunks_uploaded == 2
    assert stats.bytes_uploaded == 2048
    got = np.asarray(new_state["w"]).view(np.uint8)
    assert (got[0:4] == 255).all() and (got[1024:1028] == 255).all()
    ref = np.asarray(s["w"]).view(np.uint8)
    assert np.array_equal(got[2048:], ref[2048:])


def test_generation_guard_drops_stale_backfill():
    s = _state()
    sh = _synced_shadow(s)
    gen = sh.generation
    sh.register(s)  # re-registration bumps the generation
    before = list(sh._streams[("w", 0)].digests)
    sh.set_digests(("w", 0), [123] * len(before), generation=gen)
    assert sh._streams[("w", 0)].digests == before  # stale backfill ignored
    sh.set_digests(("w", 0), [123] * len(before), generation=sh.generation)
    assert sh._streams[("w", 0)].digests == [123] * len(before)
