"""ShadowStateManager.upload() — the write-back half of Algorithm 1 — and
the re-registration pin/retire discipline for in-flight fork children."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChunkState, HostShardView, ShadowStateManager


def _state(n=4096):
    return {"w": jnp.arange(n, dtype=jnp.float32), "b": jnp.ones((16,), jnp.float32)}


def test_upload_pushes_all_host_dirty(tmp_path):
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    w = sh.snapshot()[("w", 0)]["data"].view(np.float32)
    w[:] = -np.arange(len(w), dtype=np.float32)
    sh.mark_host_write("w")
    s2, stats = sh.upload(s)
    assert np.array_equal(np.asarray(s2["w"]), w)
    assert np.array_equal(np.asarray(s2["b"]), np.asarray(s["b"]))  # untouched
    nw = sh._streams[("w", 0)]
    assert stats.chunks_uploaded == nw.n_chunks
    assert stats.per_stream[("w", 0)] == nw.nbytes
    assert stats.per_stream.get(("b", 0)) is None
    assert all(c is ChunkState.CLEAN for c in nw.states)


def test_upload_only_moves_dirty_chunks():
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    stream = sh._streams[("w", 0)]
    buf = stream.buffer.view(np.float32)
    per_chunk = 1024 // 4
    buf[0] = 111.0                 # chunk 0: mutated but NOT marked
    buf[per_chunk] = 222.0         # chunk 1: mutated and marked
    stream.states[1] = ChunkState.HOST_DIRTY
    s2, stats = sh.upload(s)
    assert stats.chunks_uploaded == 1
    assert stats.bytes_uploaded == 1024
    out = np.asarray(s2["w"])
    assert out[per_chunk] == 222.0     # dirty chunk pushed
    assert out[0] == 0.0               # clean chunk NOT pushed (FSM honesty)


def test_upload_after_sync_roundtrips_digests():
    """Uploaded chunks become CLEAN with correct digests: a following
    mark_device_step + sync fetches nothing."""
    s = _state()
    sh = ShadowStateManager(chunk_bytes=1024)
    sh.register(s)
    sh.sync(s)
    w = sh.snapshot()[("w", 0)]["data"].view(np.float32)
    w[7] = 99.0
    sh.mark_host_write("w")
    s2, _ = sh.upload(s)
    sh.mark_device_step()
    stats = sh.sync(s2)
    assert stats.chunks_fetched == 0


def test_upload_hostshardview_patches_in_place():
    data = np.arange(64, dtype=np.float32).reshape(8, 8)
    leaf = HostShardView(
        data, start=[4, 0], stop=[8, 8], global_shape=(16, 8), dtype=np.float32
    )
    s = {"w": leaf}
    sh = ShadowStateManager(chunk_bytes=64, digest_on_device=False)
    sh.register(s)
    sh.sync(s)
    buf = sh.snapshot()[("w", 0)]["data"].view(np.float32)
    buf[:] = 5.0
    sh.mark_host_write("w")
    s2, stats = sh.upload(s)
    assert np.all(s2["w"].data == 5.0)
    assert stats.bytes_uploaded == data.nbytes


def test_upload_without_register_raises():
    sh = ShadowStateManager()
    with pytest.raises(RuntimeError, match="register"):
        sh.upload({"w": np.zeros(4, np.float32)})


def test_upload_never_synced_without_factory_raises():
    s = {"w": np.zeros(64, np.float32)}
    sh = ShadowStateManager(chunk_bytes=64)
    sh.register(s)
    sh.mark_host_write("w")
    with pytest.raises(RuntimeError, match="no shadow content"):
        sh.upload(s)


# -- re-registration vs in-flight consumers -----------------------------------

def test_reregister_unpinned_drops_old_generation():
    s = {"w": np.arange(256, dtype=np.float32)}
    sh = ShadowStateManager(chunk_bytes=256, shared_buffers=True)
    sh.register(s)
    sh.sync(s)
    old_mm = sh._mmaps[0]
    sh.register(s)  # nobody pinned: release immediately
    assert not sh._retired
    assert old_mm.closed


def test_reregister_pinned_retires_until_unpin():
    """A persisting fork child still reads the old MAP_SHARED pages:
    register() must retire them and unpin() must release them."""
    s = {"w": np.arange(256, dtype=np.float32)}
    sh = ShadowStateManager(chunk_bytes=256, shared_buffers=True)
    sh.register(s)
    sh.sync(s)
    old_mm = sh._mmaps[0]
    sh.pin()
    sh.register(s)
    assert sh._retired            # deferred, not dropped
    assert not old_mm.closed      # child could still be reading
    sh.sync(s)                    # the new generation works independently
    sh.unpin()
    assert not sh._retired
    assert old_mm.closed


def test_nested_pins_release_only_at_zero():
    s = {"w": np.arange(256, dtype=np.float32)}
    sh = ShadowStateManager(chunk_bytes=256, shared_buffers=True)
    sh.register(s)
    sh.sync(s)
    old_mm = sh._mmaps[0]
    sh.pin()
    sh.pin()
    sh.register(s)
    sh.unpin()
    assert not old_mm.closed      # one consumer still holds the generation
    sh.unpin()
    assert old_mm.closed
