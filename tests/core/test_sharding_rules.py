"""ShardingRules: param spec resolution, FSDP divisibility, cache specs."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_abstract_mesh as _amesh, make_mesh
from repro.models import build
from repro.runtime.sharding import ShardingRules, fit_spec


@pytest.fixture(scope="module")
def mesh():
    return _amesh((1, 1), ("data", "model"))


def test_fit_spec_drops_nondivisible(mesh):
    m4 = make_mesh((1,), ("data",))
    assert fit_spec(m4, P("data"), (7,)) == P("data")  # size-1 axis divides
    assert fit_spec(m4, P("nope"), (8,)) == P(None)
    assert fit_spec(m4, P("data", "data"), (4,)) == P("data")


def test_param_rules_cover_all_archs(mesh):
    for arch in ("command-r-plus-104b", "arctic-480b", "zamba2-1.2b",
                 "musicgen-medium", "paligemma-3b"):
        cfg = get_config(arch, smoke=True)
        model = build(cfg)
        rules = ShardingRules(cfg=cfg, mesh=mesh)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        specs = rules.params_specs(shapes)
        n_spec = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        n_leaf = len(jax.tree.leaves(shapes))
        assert n_spec == n_leaf


def test_tp_rules_shard_expected_dims():
    mesh = _amesh((1, 2), ("data", "model"))
    cfg = get_config("granite-8b")  # tp=True, kv=8 not divisible by 2? 8%2=0
    rules = ShardingRules(cfg=cfg, mesh=mesh)
    spec = rules.spec_for("blocks/attn/wq", (36, 4096, 4096))
    assert spec[2] == "model"
    spec_o = rules.spec_for("blocks/attn/wo", (36, 4096, 4096))
    assert spec_o[1] == "model"
    spec_e = rules.spec_for("embed", (49152, 4096))
    assert spec_e[0] == "model"
    moe_cfg = get_config("arctic-480b")
    moe_rules = ShardingRules(cfg=moe_cfg, mesh=mesh)
    spec_moe = moe_rules.spec_for("blocks/moe/wi", (35, 128, 7168, 4864))
    assert spec_moe[1] == "model"  # experts over model


def test_no_tp_means_model_axis_joins_batch():
    mesh = _amesh((1, 2), ("data", "model"))
    cfg = get_config("gemma-2b")  # tensor_parallel=False
    rules = ShardingRules(cfg=cfg, mesh=mesh)
    assert rules.model_axis is None
    assert "model" in rules.data_axes
    # batch shards over both axes when divisible
    sh = rules.batch_sharding_for((4, 128))
    assert sh.spec[0] == ("data", "model")


def test_cache_spec_head_dim_fallback():
    mesh = _amesh((1, 2), ("data", "model"))
    # command-r: kv=8 divisible by 2 -> heads sharded
    r1 = ShardingRules(cfg=get_config("command-r-plus-104b"), mesh=mesh)
    assert r1.cache_spec()[2] == "model"
    # qwen2 kv=2, but tp=False -> no model axis at all
    r2 = ShardingRules(cfg=get_config("qwen2-0.5b"), mesh=mesh)
    assert r2.cache_spec()[4] is None


def test_layer_axis_never_sharded(mesh):
    cfg = get_config("granite-8b")
    rules = ShardingRules(cfg=cfg, mesh=mesh)
    for path, shape in [
        ("blocks/attn/wq", (36, 4096, 4096)),
        ("blocks/mlp/wi", (36, 4096, 14336)),
        ("blocks/moe/wi", (36, 8, 4096, 1408)),
    ]:
        assert rules.spec_for(path, shape)[0] is None
