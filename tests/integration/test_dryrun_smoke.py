"""Dry-run smoke: the production-mesh lowering machinery works end-to-end,
exercised in a subprocess with 64 forced host devices and an 8x8 mesh
(fast); the full 512-device 40-cell sweep runs via launch/dryrun.py --all
and is recorded in EXPERIMENTS.md.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
    import json
    import jax, numpy as np
    import repro.launch.dryrun as dr

    # shrink the production mesh to 8x8 / 2x4x8 for CI speed
    import repro.launch.mesh as mesh_mod
    def small_mesh(*, multi_pod=False):
        shape = (2, 4, 8) if multi_pod else (8, 8)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return mesh_mod.make_mesh(shape, axes)
    dr.make_production_mesh = small_mesh

    recs = []
    for mesh_kind in ("single", "multi"):
        rec = dr.run_cell("qwen2-0.5b", "train_4k", mesh_kind,
                          overrides={"num_layers": 2})
        recs.append(rec)
    rec = dr.run_cell("mamba2-130m", "long_500k", "single",
                      overrides={"num_layers": 2})
    recs.append(rec)
    rec = dr.run_cell("granite-8b", "decode_32k", "single",
                      overrides={"num_layers": 2})
    recs.append(rec)
    # skip semantics
    rec = dr.run_cell("granite-8b", "long_500k", "single")
    recs.append(rec)
    print("RESULTS=" + json.dumps([{k: r.get(k) for k in ("arch","shape","mesh","status")} for r in recs]))
    """
)


@pytest.mark.slow
def test_dryrun_lowers_and_compiles_on_both_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS=")][-1]
    recs = json.loads(line[len("RESULTS="):])
    by = {(r["arch"], r["shape"], r["mesh"]): r["status"] for r in recs}
    assert by[("qwen2-0.5b", "train_4k", "single")] == "ok"
    assert by[("qwen2-0.5b", "train_4k", "multi")] == "ok"
    assert by[("mamba2-130m", "long_500k", "single")] == "ok"
    assert by[("granite-8b", "decode_32k", "single")] == "ok"
    assert by[("granite-8b", "long_500k", "single")] == "skip"
