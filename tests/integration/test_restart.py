"""Restart correctness: interrupted-and-restored == uninterrupted (bitwise).

The paper's Q2 ("does CRUM provide the ability to checkpoint?") made
rigorous: a run that checkpoints at step k, dies, and restores must produce
exactly the same parameters at step N as a run that never died — including
the data-pipeline cursor and optimizer state. Exercised over both persist
backends (thread writer-pool and true-COW fork).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointedTrainer, CheckpointPolicy
from repro.data import SyntheticBatches
from repro.models import ModelConfig, build
from repro.optim import get_optimizer
from repro.utils.tree import tree_equal

BACKENDS = ["thread"] + (["fork"] if hasattr(os, "fork") else [])


def _cfg():
    return ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        param_dtype="float32", compute_dtype="float32",
    )


def _setup(cfg):
    model = build(cfg)
    opt = get_optimizer("adamw", 1e-3)

    @jax.jit
    def step_fn(dstate, batch):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(
            dstate["params"], batch
        )
        p2, o2 = opt.update(g, dstate["opt"], dstate["params"], dstate["step"])
        return {"params": p2, "opt": o2, "step": dstate["step"] + 1}, {"loss": l}

    def init_state():
        params = model.init(jax.random.key(0))
        return {
            "device": {
                "params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32),
            },
            "host": {
                "step": np.int64(0),
                "data": SyntheticBatches(cfg, batch=4, seq_len=16).state(),
            },
        }

    return model, step_fn, init_state


def _run(cfg, step_fn, state, data, n_steps, trainer=None):
    for _ in range(n_steps):
        batch = jax.tree.map(jnp.asarray, next(data))
        state["device"], _ = step_fn(state["device"], batch)
        step = int(np.asarray(state["host"]["step"])) + 1
        state["host"]["step"] = np.int64(step)
        state["host"]["data"] = data.state()
        if trainer is not None and trainer.policy.should_checkpoint(step):
            trainer.checkpoint_now(step, state)
    return state


@pytest.mark.parametrize("backend", BACKENDS)
def test_restart_is_bitwise_identical(tmp_path, backend):
    cfg = _cfg()
    model, step_fn, init_state = _setup(cfg)

    # reference: 10 uninterrupted steps
    ref_state = init_state()
    ref_data = SyntheticBatches(cfg, batch=4, seq_len=16)
    ref_state = _run(cfg, step_fn, ref_state, ref_data, 10)

    # interrupted: checkpoint every 4 steps, die at 7
    trainer = CheckpointedTrainer(
        step_fn, store_root=str(tmp_path / "ck"),
        policy=CheckpointPolicy(interval_steps=4, keep_last=3),
        chunk_bytes=1 << 12, backend=backend,
    )
    st = init_state()
    data = SyntheticBatches(cfg, batch=4, seq_len=16)
    st = _run(cfg, step_fn, st, data, 7, trainer)
    trainer.checkpointer.wait_all()
    del st  # "crash" — everything after the last checkpoint is lost

    # restore (latest committed = step 4) and continue to 10
    restored, start = trainer.resume_or(init_state)
    assert start == 4
    data2 = SyntheticBatches.from_state(
        cfg, batch=4, seq_len=16, state=restored["host"]["data"]
    )
    restored["device"] = jax.tree.map(jnp.asarray, restored["device"])
    restored = _run(cfg, step_fn, restored, data2, 10 - start)
    trainer.finish()

    assert tree_equal(
        jax.tree.map(np.asarray, ref_state["device"]["params"]),
        jax.tree.map(np.asarray, restored["device"]["params"]),
    ), "restored run diverged from uninterrupted run"


def test_resume_or_fresh_when_no_checkpoint(tmp_path):
    cfg = _cfg()
    _, step_fn, init_state = _setup(cfg)
    trainer = CheckpointedTrainer(step_fn, store_root=str(tmp_path / "empty"))
    state, start = trainer.resume_or(init_state)
    assert start == 0
    trainer.finish()


def test_gc_respects_keep_last(tmp_path):
    cfg = _cfg()
    model, step_fn, init_state = _setup(cfg)
    trainer = CheckpointedTrainer(
        step_fn, store_root=str(tmp_path / "gc"),
        policy=CheckpointPolicy(interval_steps=2, keep_last=2),
        incremental=False, chunk_bytes=1 << 12,
    )
    st = init_state()
    data = SyntheticBatches(cfg, batch=4, seq_len=16)
    st = _run(cfg, step_fn, st, data, 8, trainer)
    trainer.finish()
    from repro.checkpoint.manifest import committed_steps

    left = committed_steps(str(tmp_path / "gc"))
    assert left == [6, 8]
