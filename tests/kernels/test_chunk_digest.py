"""chunk_digest kernel: oracle equality across shapes/dtypes + properties."""
import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from repro.utils.testing import given, settings, st

from repro.checkpoint.chunking import chunk_digest_np
from repro.kernels import ops, ref

DTYPES = [np.float32, np.int32, np.int8, np.uint8, np.float16, ml_dtypes.bfloat16]
SHAPES = [(17,), (1024,), (257, 33), (1, 1), (4096,), (63, 7, 5)]
CHUNKS = [64, 256, 4096]


def _rand(rng, dtype, shape):
    dt = np.dtype(dtype)
    if dt.kind == "f" or dt == np.dtype(ml_dtypes.bfloat16):
        return rng.standard_normal(shape).astype(np.float32).astype(dt)
    return rng.integers(0, 100, shape).astype(dt)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_jnp_fallback_matches_numpy_oracle(rng, dtype, shape):
    x = _rand(rng, dtype, shape)
    for cb in CHUNKS:
        want = ref.chunk_digests_np(x, cb)
        got = np.asarray(ops.chunk_digests(jnp.asarray(x), cb, use_pallas="ref"))
        assert np.array_equal(want, got), (dtype, shape, cb)


@pytest.mark.parametrize("shape,cb", [
    ((1024,), 256), ((100_000,), 4096), ((7, 130), 512),
    ((2**20,), 4 << 20), ((2**18 + 3,), 65536),
])
def test_pallas_interpret_matches_oracle(rng, shape, cb):
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    want = ref.chunk_digests_np(np.asarray(x), cb)
    got = np.asarray(ops.chunk_digests(x, cb, use_pallas="interpret"))
    assert np.array_equal(want, got)


def test_digest_detects_single_byte_change(rng):
    x = rng.integers(0, 255, 8192).astype(np.uint8)
    d1 = ref.chunk_digests_np(x, 1024)
    y = x.copy()
    y[5000] ^= 1
    d2 = ref.chunk_digests_np(y, 1024)
    changed = [i for i in range(len(d1)) if tuple(d1[i]) != tuple(d2[i])]
    assert changed == [5000 // 1024]


def test_digest_is_order_sensitive():
    a = np.arange(64, dtype=np.uint32)
    b = a[::-1].copy()
    assert chunk_digest_np(a) != chunk_digest_np(b)


@settings(max_examples=50, deadline=None)
@given(
    data=st.binary(min_size=0, max_size=2048),
    cb=st.sampled_from([64, 128, 1024]),
)
def test_property_digest_deterministic_and_change_sensitive(data, cb):
    d1 = chunk_digest_np(data)
    d2 = chunk_digest_np(data)
    assert d1 == d2
    if len(data) >= 1:
        mutated = bytearray(data)
        mutated[0] ^= 0xFF
        assert chunk_digest_np(bytes(mutated)) != d1


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31),
    cb=st.sampled_from([64, 256]),
)
def test_property_device_equals_host(n, seed, cb):
    r = np.random.default_rng(seed)
    x = r.standard_normal(n).astype(np.float32)
    want = ref.chunk_digests_np(x, cb)
    got = np.asarray(ops.chunk_digests(jnp.asarray(x), cb, use_pallas="ref"))
    assert np.array_equal(want, got)
