"""flash_attention kernel: shape/dtype sweep vs dense oracle (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(rng, B, Hq, Hkv, Sq, Sk, D, dtype):
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Sk, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D", [
    (1, 1, 1, 128, 128, 64),
    (2, 4, 2, 256, 256, 64),      # GQA
    (1, 8, 1, 128, 128, 128),     # MQA
    (1, 4, 4, 128, 512, 64),      # decode-aligned Sq < Sk
    (2, 2, 2, 384, 384, 32),      # non-pow2 seq (3 blocks of 128)
])
def test_matches_oracle_f32(rng, B, Hq, Hkv, Sq, Sk, D):
    q, k, v = _qkv(rng, B, Hq, Hkv, Sq, Sk, D, jnp.float32)
    want = ref.mha_reference(q, k, v, causal=True)
    got = ops.flash_attention(q, k, v, causal=True, use_pallas="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_matches_oracle_bf16(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 128, 128, 64, jnp.bfloat16)
    want = ref.mha_reference(q, k, v, causal=True)
    got = ops.flash_attention(q, k, v, causal=True, use_pallas="interpret")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_non_causal(rng):
    q, k, v = _qkv(rng, 1, 2, 1, 128, 256, 64, jnp.float32)
    want = ref.mha_reference(q, k, v, causal=False)
    got = ops.flash_attention(q, k, v, causal=False, use_pallas="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_block_shape_independence(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 256, 256, 64, jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=128, block_k=128, use_pallas="interpret")
    b = ops.flash_attention(q, k, v, block_q=64, block_k=256, use_pallas="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_scale_override(rng):
    q, k, v = _qkv(rng, 1, 1, 1, 128, 128, 64, jnp.float32)
    want = ref.mha_reference(q, k, v, causal=True, scale=0.5)
    got = ops.flash_attention(q, k, v, causal=True, scale=0.5, use_pallas="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_chunked_jnp_path_matches_dense(rng):
    """The pure-JAX blocked attention (models/layers) == dense oracle."""
    from repro.models.layers import _chunked_attention, _dense_attention

    q, k, v = _qkv(rng, 2, 4, 2, 256, 256, 32, jnp.float32)
    dense = _dense_attention(q, k, v, causal=True, prefix_len=None, scale=0.1767767)
    blocked = _chunked_attention(
        q, k, v, causal=True, prefix_len=None, scale=0.1767767,
        block_q=64, block_k=128,
    )
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense), atol=2e-5, rtol=2e-5)


def test_prefix_lm_mask(rng):
    """prefix_len makes the first P tokens bidirectional."""
    from repro.models.layers import _dense_attention

    q, k, v = _qkv(rng, 1, 1, 1, 8, 8, 16, jnp.float32)
    causal = _dense_attention(q, k, v, causal=True, prefix_len=None, scale=0.25)
    prefix = _dense_attention(q, k, v, causal=True, prefix_len=4, scale=0.25)
    # rows >= prefix see identical mask only if their causal window covers
    # the prefix — row 7 attends all of 0..7 either way
    np.testing.assert_allclose(
        np.asarray(causal)[:, :, 7], np.asarray(prefix)[:, :, 7], atol=1e-6
    )
    # row 0 differs: prefix mode lets it see cols 1..3
    assert not np.allclose(np.asarray(causal)[:, :, 0], np.asarray(prefix)[:, :, 0])
