"""Per-arch smoke: reduced config, one forward + one train step on CPU.

Covers all 10 assigned architectures (reduced same-family configs); full
configs are exercised via the dry-run only (ShapeDtypeStruct, no alloc).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.data import SyntheticBatches
from repro.models import build
from repro.optim import get_optimizer


def _batch(cfg, B=2, S=32):
    data = SyntheticBatches(cfg, batch=B, seq_len=S)
    return jax.tree.map(jnp.asarray, next(data))


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    # forward: shape + finiteness
    logits = model.forward(params, batch)
    assert logits.shape[0] == 2
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one train step: loss finite, params move
    opt = get_optimizer(cfg.optimizer, 1e-3)
    opt_state = opt.init(params)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    new_params, _ = opt.update(grads, opt_state, params, jnp.zeros((), jnp.int32))
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.abs(b.astype(jnp.float32)).sum()),
        jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                     new_params, params),
        0.0,
    )
    assert delta > 0, f"{arch}: optimizer produced no update"


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a).frontend != "vision"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    if model.decode is None:
        pytest.skip("no decode path")
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, B=2, S=16)
    logits_full = model.forward(params, batch)
    cache = model.init_cache(2, 16)
    errs = []
    for t in range(16):
        tok = batch["inputs"][:, t]
        lg, cache = model.decode(params, cache, tok)
        want = logits_full[:, t]
        errs.append(float(jnp.abs(lg - want).max()))
    assert max(errs) < 2e-2, f"{arch}: decode diverges from forward ({max(errs)})"


def test_vlm_prefill_decode_consistency():
    cfg = get_config("paligemma-3b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, B=2, S=16)
    logits_full = model.forward(params, batch)  # text logits
    lp, cache = model.prefill(
        params, {"patches": batch["patches"], "inputs": batch["inputs"][:, :10]}, 64
    )
    assert np.allclose(np.asarray(lp[:, 0]), np.asarray(logits_full[:, 9]), atol=2e-2)
    ld, cache = model.decode(params, cache, batch["inputs"][:, 10])
    assert np.allclose(np.asarray(ld), np.asarray(logits_full[:, 10]), atol=2e-2)


def test_vlm_uses_patches():
    cfg = get_config("paligemma-3b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    l1 = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 10.0
    l2 = model.forward(params, batch2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2)), "patches ignored"


def test_moe_router_balances_under_training():
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    _, metrics = model.loss(params, batch)
    assert float(metrics["aux"]) > 0  # balance loss active


def test_param_counts_match_analytic():
    """init() allocates exactly cfg.n_params() parameters (full configs,
    via eval_shape — no memory)."""
    for arch in list_archs():
        cfg = get_config(arch)
        model = build(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        analytic = cfg.n_params()
        assert abs(total - analytic) / analytic < 0.02, (
            f"{arch}: init {total:,} vs analytic {analytic:,}"
        )
