"""Loss equivalences, optimizers, schedule, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency ([test] extra)")
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticBatches
from repro.models import ModelConfig, build
from repro.models.zoo import chunked_lm_xent, softmax_xent
from repro.optim import get_optimizer, global_norm, warmup_cosine


def _tiny(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=64, vocab_size=128,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        param_dtype="float32", compute_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_chunked_ce_equals_full_ce_and_grads(rng):
    cfg = _tiny(ce_chunk_tokens=0)
    m_full = build(cfg)
    m_chun = build(cfg.with_overrides(ce_chunk_tokens=8))
    params = m_full.init(jax.random.key(0))
    batch = {
        "inputs": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32),
    }
    lf, _ = m_full.loss(params, batch)
    lc, _ = m_chun.loss(params, batch)
    assert abs(float(lf) - float(lc)) < 1e-5
    gf = jax.grad(lambda p: m_full.loss(p, batch)[0])(params)
    gc = jax.grad(lambda p: m_chun.loss(p, batch)[0])(params)
    err = jax.tree.reduce(
        lambda a, b: max(a, float(jnp.abs(b).max())),
        jax.tree.map(lambda a, b: a - b, gf, gc), 0.0,
    )
    assert err < 1e-6


@pytest.mark.parametrize("name", ["adamw", "adafactor", "q8adam"])
def test_optimizer_reduces_loss(name, rng):
    cfg = _tiny()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    opt = get_optimizer(name, 1e-2)
    state = opt.init(params)
    batch = {
        "inputs": jnp.asarray(rng.integers(0, 128, (4, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 128, (4, 32)), jnp.int32),
    }

    @jax.jit
    def step(params, state, i):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        p2, s2 = opt.update(g, state, params, i)
        return p2, s2, l

    losses = []
    for i in range(8):
        params, state, l = step(params, state, jnp.asarray(i))
        losses.append(float(l))
    assert losses[-1] < losses[0], (name, losses)


def test_q8_state_is_actually_int8(rng):
    cfg = _tiny()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    s = get_optimizer("q8adam", 1e-3).init(params)
    kinds = {str(l.dtype) for l in jax.tree.leaves(s["m"])}
    assert "int8" in kinds
    v_kinds = {str(l.dtype) for l in jax.tree.leaves(s["v"])}
    assert v_kinds == {"bfloat16"}


def test_adafactor_memory_is_sublinear(rng):
    cfg = _tiny()
    model = build(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    opt_shapes = jax.eval_shape(
        lambda: get_optimizer("adafactor", 1e-3).init(shapes)
    )
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    n_opt = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(opt_shapes))
    assert n_opt < 0.25 * n_params  # factored: rows+cols only for matrices


def test_grad_clip_bounds_norm(rng):
    from repro.optim import clip_by_global_norm

    tree = {"a": jnp.full((100,), 100.0), "b": jnp.full((10, 10), -50.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 0.11
    assert float(sched(100)) < float(sched(50)) < float(sched(10)) + 1e-6


# -- data pipeline --------------------------------------------------------------

def test_data_deterministic_given_state():
    cfg = _tiny()
    a = SyntheticBatches(cfg, batch=4, seq_len=16, seed=7)
    for _ in range(5):
        next(a)
    state = a.state()
    b1 = next(a)
    resumed = SyntheticBatches.from_state(cfg, batch=4, seq_len=16, state=state)
    b2 = next(resumed)
    assert np.array_equal(b1["inputs"], b2["inputs"])
    assert np.array_equal(b1["targets"], b2["targets"])


def test_data_targets_are_shifted_inputs():
    cfg = _tiny()
    b = next(SyntheticBatches(cfg, batch=2, seq_len=16))
    assert np.array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_data_vocab_bounds():
    cfg = _tiny(vocab_size=32)
    b = next(SyntheticBatches(cfg, batch=8, seq_len=64))
    assert b["inputs"].min() >= 0 and b["inputs"].max() < 32


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), steps=st.integers(0, 20))
def test_property_data_state_roundtrip(seed, steps):
    cfg = _tiny()
    a = SyntheticBatches(cfg, batch=2, seq_len=8, seed=seed)
    for _ in range(steps):
        next(a)
    b = SyntheticBatches.from_state(cfg, batch=2, seq_len=8, state=a.state())
    assert np.array_equal(next(a)["inputs"], next(b)["inputs"])
