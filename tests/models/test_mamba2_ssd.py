"""Mamba2 SSD: chunked algorithm vs sequential-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.mamba2 import (
    init_ssm_state,
    mamba_init,
    ssd_forward,
    ssd_reference,
    ssm_decode_step,
)


def _cfg(chunk=8, state=16, head_dim=16, d_model=32):
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=d_model, vocab_size=64,
        ssm_state=state, ssm_head_dim=head_dim, ssm_chunk=chunk,
        param_dtype="float32", compute_dtype="float32",
    )


@pytest.mark.parametrize("S,chunk", [(24, 8), (32, 32), (16, 4), (64, 16)])
def test_ssd_equals_recurrence(rng, S, chunk):
    cfg = _cfg(chunk=chunk)
    p = mamba_init(jax.random.key(1), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, S, 32)) * 0.5, jnp.float32)
    y_ssd, _ = ssd_forward(cfg, p, x)
    y_ref = ssd_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_ssd), np.asarray(y_ref), atol=2e-3, rtol=1e-3)


def test_final_state_continues_generation(rng):
    """State after ssd_forward must equal state after stepping the prompt."""
    cfg = _cfg()
    p = mamba_init(jax.random.key(2), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 16, 32)) * 0.5, jnp.float32)
    _, final = ssd_forward(cfg, p, x)
    state = init_ssm_state(cfg, 1)
    for t in range(16):
        _, state = ssm_decode_step(cfg, p, state, x[:, t : t + 1])
    np.testing.assert_allclose(
        np.asarray(final["h"]), np.asarray(state["h"]), atol=2e-3, rtol=1e-3
    )
    # conv window continues exactly as well
    np.testing.assert_allclose(
        np.asarray(final["conv"]), np.asarray(state["conv"]), atol=2e-3, rtol=1e-3
    )


def test_decay_bounds(rng):
    """A < 0 guarantees the recurrence is stable (decay in (0,1))."""
    cfg = _cfg()
    p = mamba_init(jax.random.key(3), cfg, jnp.float32)
    A = -jnp.exp(p["A_log"])
    assert bool((A < 0).all())


def test_conv_cache_consistency(rng):
    """Decode conv window must reproduce the causal conv of the full pass."""
    cfg = _cfg(chunk=4)
    p = mamba_init(jax.random.key(4), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 8, 32)) * 0.5, jnp.float32)
    y_full, _ = ssd_forward(cfg, p, x)
    state = init_ssm_state(cfg, 1)
    ys = []
    for t in range(8):
        y, state = ssm_decode_step(cfg, p, state, x[:, t : t + 1])
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps), atol=2e-3, rtol=1e-3)
