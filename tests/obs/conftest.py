"""Observability tests mutate process-global state (the module tracer,
the metrics registry, CRUM_OBS_* env) — restore all of it per test."""
import pytest

from repro.obs import trace
from repro.obs.metrics import REGISTRY


@pytest.fixture(autouse=True)
def _obs_hygiene():
    yield
    trace.disable()  # closes the shard fd and pops CRUM_OBS_DIR/_RUN
    REGISTRY.reset()
