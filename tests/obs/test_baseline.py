"""Baseline compare: identical passes, injected regression flags, history."""
import copy
import json
import os

from repro.obs import baseline

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..")
)

BASE_ROWS = [
    {"name": "fig4_proxy_overhead_pipelined_kernelish_2ms_step",
     "us_per_call": 2100.0, "overhead_pct": 4.2,
     "within_paper_envelope": True},
    {"name": "proxy_kill_replay_recovery", "us_per_call": 900.0,
     "bit_identical": True},
    {"name": "fused_digest_boundary_fused", "us_per_call": 150.0,
     "boundary_scan_gone": True},
    {"name": "obs_noop_hook", "us_per_call": 0.02},
]


def test_identical_rows_pass():
    assert baseline.compare(copy.deepcopy(BASE_ROWS), BASE_ROWS) == []


def test_committed_baseline_vs_itself_passes():
    """The acceptance criterion: --compare on the committed baseline is
    deterministic-green (same file on both sides)."""
    _, rows = baseline.load_rows(
        os.path.join(REPO_ROOT, "BENCH_results.json")
    )
    assert rows, "committed BENCH_results.json must have rows"
    assert baseline.compare(rows, rows) == []


def test_injected_perf_regression_flags():
    fresh = copy.deepcopy(BASE_ROWS)
    fresh[1]["us_per_call"] = 900.0 * 4  # inject a 4x slowdown
    findings = baseline.compare(fresh, BASE_ROWS, ratio=3.0)
    [f] = findings
    assert f["kind"] == "perf_regression"
    assert f["name"] == "proxy_kill_replay_recovery"
    assert f["ratio"] == 4.0


def test_jitter_below_ratio_passes():
    fresh = copy.deepcopy(BASE_ROWS)
    fresh[0]["us_per_call"] *= 2.5  # big jitter, still under the 3x fence
    assert baseline.compare(fresh, BASE_ROWS, ratio=3.0) == []


def test_tiny_rows_skip_perf_rule():
    """A 0.02us hook timing is pure noise — never a perf finding."""
    fresh = copy.deepcopy(BASE_ROWS)
    fresh[3]["us_per_call"] = 0.4  # 20x, but sub-min_us
    assert baseline.compare(fresh, BASE_ROWS) == []


def test_hard_boolean_flip_flags():
    fresh = copy.deepcopy(BASE_ROWS)
    fresh[1]["bit_identical"] = False
    fresh[2].pop("boundary_scan_gone")  # vanished counts as flipped
    kinds = {(f["kind"], f.get("key")) for f in
             baseline.compare(fresh, BASE_ROWS)}
    assert ("hard_flip", "bit_identical") in kinds
    assert ("hard_flip", "boundary_scan_gone") in kinds


def test_missing_row_detection_and_optout():
    fresh = [r for r in copy.deepcopy(BASE_ROWS)
             if r["name"] != "obs_noop_hook"]
    findings = baseline.compare(fresh, BASE_ROWS)
    assert [f["kind"] for f in findings] == ["missing_row"]
    assert baseline.compare(fresh, BASE_ROWS, check_missing=False) == []


def test_new_rows_never_flag():
    """Growth is not a regression: fresh-only rows are ignored."""
    fresh = copy.deepcopy(BASE_ROWS) + [
        {"name": "brand_new_bench", "us_per_call": 1e9}
    ]
    assert baseline.compare(fresh, BASE_ROWS) == []


def test_history_append(tmp_path):
    path = str(tmp_path / "BENCH_history.jsonl")
    doc = {"timestamp": "2026-08-07T00:00:00+00:00", "git_rev": "abc",
           "failed": [], "rows": BASE_ROWS}
    baseline.append_history(path, doc, [], baseline_rev="base123")
    baseline.append_history(
        path, doc,
        [{"kind": "perf_regression", "name": "x", "message": "m"}],
    )
    lines = [json.loads(x) for x in open(path)]
    assert len(lines) == 2
    assert lines[0]["schema"] == baseline.BASELINE_SCHEMA
    assert lines[0]["n_findings"] == 0
    assert lines[0]["baseline_rev"] == "base123"
    assert lines[1]["finding_kinds"] == ["perf_regression"]
    assert "obs_noop_hook" in lines[0]["headline"]


def test_cli_exit_codes(tmp_path):
    fresh_ok = str(tmp_path / "fresh.json")
    with open(fresh_ok, "w") as f:
        json.dump({"rows": copy.deepcopy(BASE_ROWS)}, f)
    base = str(tmp_path / "base.json")
    with open(base, "w") as f:
        json.dump({"rows": BASE_ROWS, "git_rev": "b"}, f)
    hist = str(tmp_path / "hist.jsonl")
    assert baseline.main([fresh_ok, "--baseline", base,
                          "--history", hist]) == 0

    bad_rows = copy.deepcopy(BASE_ROWS)
    bad_rows[1]["us_per_call"] *= 10
    fresh_bad = str(tmp_path / "bad.json")
    with open(fresh_bad, "w") as f:
        json.dump({"rows": bad_rows}, f)
    assert baseline.main([fresh_bad, "--baseline", base,
                          "--history", hist]) == 1
    assert len(open(hist).readlines()) == 2
    # no baseline file: informational skip, not a failure
    assert baseline.main([fresh_ok, "--baseline",
                          str(tmp_path / "nope.json")]) == 0
