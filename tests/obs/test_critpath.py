"""Critical-path attribution: causal trees, phase sweep, orphans, drills.

Unit tests drive :mod:`repro.obs.critpath` over synthetic shards (events
written by hand, journal lines with pinned timestamps); the
``integration``-marked tests run a real 2-host cluster with tracing on
and assert the acceptance criteria — every committed round rooted, the
phase decomposition summing to the round span, ``--check`` green — plus
the divergence-provenance and kill→replay drills.
"""
import json
import os

import pytest

from repro.obs import critpath, report, trace
from repro.obs.trace import root_span_id

T0 = 100_000_000.0  # µs wall; the journal line below says t=100.0009 s
ROOT = root_span_id("round:3")
TRACE = "round:3"


def _ev(name, ph, ts, pid=1, tid=1, **kw):
    ev = {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid}
    ev.update(kw)
    return ev


def _round_events():
    """One committed round: coord root, one worker subtree, commit."""
    a = dict  # arg-dict shorthand
    return [
        _ev("coord.round", "B", T0, pid=1,
            args=a(step=3, trace=TRACE, span=ROOT)),
        _ev("worker.round", "X", T0 - 20, dur=1010, pid=2,
            args=a(step=3, host=0, trace=TRACE, span=10, parent=ROOT)),
        _ev("proxy.step", "X", T0 + 10, dur=200, pid=3,
            args=a(step=3, trace=TRACE, span=11, parent=10)),
        _ev("app.sync_stall", "X", T0 + 220, dur=80, pid=2,
            args=a(trace=TRACE, span=12, parent=10)),
        _ev("ckpt.phase1", "X", T0 + 300, dur=100, pid=2,
            args=a(step=3, trace=TRACE, span=13, parent=10)),
        _ev("ckpt.persist", "X", T0 + 400, dur=400, pid=2,
            args=a(step=3, trace=TRACE, span=14, parent=13)),
        _ev("coord.commit", "X", T0 + 850, dur=100, pid=1,
            args=a(step=3, trace=TRACE, span=90, parent=ROOT)),
        _ev("coord.round", "E", T0 + 1000, pid=1),
    ]


def _write_run(tmp_path, events, journal_lines):
    run = str(tmp_path / "obs")
    os.makedirs(run, exist_ok=True)
    with open(os.path.join(run, "trace-app-1.jsonl"), "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    with open(os.path.join(run, "CLUSTER_LOG.jsonl"), "w") as f:
        for line in journal_lines:
            f.write(json.dumps(line) + "\n")
    return run


def _journal_round(step=3, status="committed", t=100.0009, round_s=0.001):
    return {"schema": "crum-cluster-log/1", "event": "round", "t": t,
            "step": step, "status": status, "round_s": round_s}


# -- span reconstruction -----------------------------------------------------

def test_build_spans_closes_be_pairs_and_marks_unclosed():
    events = [
        _ev("worker.round", "B", 10.0, args={"span": 1, "trace": "t"}),
        _ev("worker.round", "E", 30.0),
        _ev("coord.round", "B", 5.0, pid=2,
            args={"span": 2, "trace": "t"}),  # SIGKILL: never closed
        _ev("ckpt.persist", "X", 12.0, dur=6.0,
            args={"span": 3, "parent": 1, "trace": "t"}),
        _ev("coord.ack", "i", 20.0, pid=2,
            args={"span": 4, "parent": 1, "trace": "t"}),
        _ev("untagged", "i", 21.0, args={}),  # no ctx: not a tree node
    ]
    spans = critpath.build_spans(events)
    by = {s["span"]: s for s in spans if s["span"] is not None}
    assert by[1]["end"] == 30.0 and not by[1]["incomplete"]
    assert by[2]["end"] is None and by[2]["incomplete"]
    assert by[3]["end"] == 18.0
    assert by[4]["ts"] == by[4]["end"] == 20.0  # instants are zero-dur
    assert len(spans) == 4  # the ctx-less instant never becomes a span


# -- the report over a synthetic committed round -----------------------------

def test_committed_round_is_rooted_and_phases_sum_to_span(tmp_path):
    run = _write_run(tmp_path, _round_events(), [_journal_round()])
    doc = critpath.analyze(run)
    assert doc["schema"] == critpath.CRITPATH_SCHEMA
    [r] = doc["rounds"]
    assert r["status"] == "committed" and r["rooted"]
    assert r["orphan_spans"] == 0 and r["n_spans"] == 7
    assert r["span_s"] == pytest.approx(0.001)
    ph = r["phases_us"]
    assert ph["step_compute"] == pytest.approx(200)
    assert ph["sync_stall"] == pytest.approx(80)
    assert ph["phase1"] == pytest.approx(100)
    assert ph["persist"] == pytest.approx(400)
    assert ph["commit"] == pytest.approx(100)
    assert ph["wait"] == pytest.approx(120)
    # the acceptance criterion: buckets sum to the round span exactly
    assert sum(ph.values()) == pytest.approx(r["span_s"] * 1e6)
    assert r["per_host_us"]["0"]["persist"] == pytest.approx(400)
    assert critpath.check(doc) == []


def test_critical_path_descends_into_latest_finisher(tmp_path):
    run = _write_run(tmp_path, _round_events(), [_journal_round()])
    [r] = critpath.analyze(run)["rounds"]
    names = [p["name"] for p in r["critical_path"]]
    # the persist chain held the round open, not the commit fsync
    assert names == ["coord.round", "worker.round", "ckpt.phase1",
                     "ckpt.persist"]
    assert r["critical_host"] == "0"


def test_orphans_fail_check_only_without_journaled_deaths(tmp_path):
    stray = _ev("proxy.step", "X", T0 + 30, dur=10, pid=4,
                args={"trace": TRACE, "span": 20, "parent": 999})
    run = _write_run(tmp_path, _round_events() + [stray],
                     [_journal_round()])
    doc = critpath.analyze(run)
    [r] = doc["rounds"]
    assert r["orphan_spans"] == 1
    assert any("orphan" in p for p in critpath.check(doc))
    # the same orphan is the *expected* residue once a death is journaled
    run2 = _write_run(
        tmp_path / "killed", _round_events() + [stray],
        [_journal_round(),
         {"event": "death", "t": 100.0002, "host": 1, "reason": "kill"}],
    )
    doc2 = critpath.analyze(run2)
    assert doc2["deaths"] == 1
    assert critpath.check(doc2) == []


def test_span_vs_journal_disagreement_fails_check(tmp_path):
    # stretch the root to 0.5 s while the journal claims 1.0 s
    events = _round_events()
    events[-1]["ts"] = T0 + 500_000
    run = _write_run(tmp_path, events,
                     [_journal_round(t=100.4, round_s=1.0)])
    doc = critpath.analyze(run)
    assert any("apart" in p for p in critpath.check(doc))


def test_retried_round_selects_attempt_containing_commit_time(tmp_path):
    # two attempts share the deterministic root id; the journal's commit
    # timestamp falls inside the second
    retry = [
        _ev("coord.round", "B", T0 + 5000, pid=1,
            args={"step": 3, "trace": TRACE, "span": ROOT}),
        _ev("coord.round", "E", T0 + 6000, pid=1),
    ]
    run = _write_run(
        tmp_path, _round_events() + retry,
        [_journal_round(status="aborted", t=100.0008),
         _journal_round(t=100.0055)],
    )
    doc = critpath.analyze(run)
    committed = [r for r in doc["rounds"] if r["status"] == "committed"]
    [r] = committed
    assert r["span_s"] == pytest.approx(0.001)  # the 5000..6000 attempt


def test_unclaimed_trace_is_reported_as_stray(tmp_path):
    trailing = [_ev("proxy.step", "X", T0 + 9000, dur=10, pid=3,
                    args={"trace": "round:6", "span": 30, "parent": 31})]
    run = _write_run(tmp_path, _round_events() + trailing,
                     [_journal_round()])
    doc = critpath.analyze(run)
    [stray] = doc["orphans"]
    assert stray["trace"] == "round:6" and stray["orphan_spans"] == 1
    assert critpath.check(doc) == []  # trailing windows are not fatal


def test_cli_check_and_json(tmp_path, capsys):
    run = _write_run(tmp_path, _round_events(), [_journal_round()])
    out = os.path.join(run, "critpath.json")
    assert critpath.main([run, "--check", "--json", out]) == 0
    with open(out) as f:
        doc = json.load(f)
    assert doc["schema"] == critpath.CRITPATH_SCHEMA
    assert "check OK" in capsys.readouterr().out


# -- Perfetto flow stitching -------------------------------------------------

def test_flow_events_pair_resolved_edges():
    events = _round_events()
    flows = critpath.flow_events(events)
    # 6 child spans with a present parent -> 6 s/f pairs
    assert len(flows) == 12
    starts = [f for f in flows if f["ph"] == "s"]
    finishes = [f for f in flows if f["ph"] == "f"]
    assert len(starts) == len(finishes) == 6
    assert all(f["bp"] == "e" for f in finishes)
    assert {f["id"] for f in starts} == {f["id"] for f in finishes}
    # flow events are schema-valid phases for the merged-trace check
    assert report.validate_events(flows) == []


def test_merge_stitches_flow_arrows(tmp_path):
    run = _write_run(tmp_path, _round_events(), [_journal_round()])
    out, events, _ = report.merge(run)
    with open(out) as f:
        doc = json.load(f)
    assert any(ev.get("ph") == "s" for ev in doc["traceEvents"])


# -- real-cluster integration ------------------------------------------------

@pytest.mark.integration
def test_cluster_rounds_all_rooted_and_check_green(tmp_path):
    from repro.coord.supervisor import run_cluster

    root = str(tmp_path / "ckpt")
    obs = str(tmp_path / "obs")
    rep = run_cluster(
        root=root, n_hosts=2, total_steps=4, ckpt_every=2,
        backend="thread", loop="numpy", deadline_s=180.0, obs_dir=obs,
    )
    assert rep.latest_committed == 4 and rep.alerts == []
    jpath = os.path.join(root, "CLUSTER_LOG.jsonl")
    doc = critpath.analyze(obs, journal=jpath)
    committed = [r for r in doc["rounds"] if r["status"] == "committed"]
    assert {r["step"] for r in committed} == {2, 4}
    for r in committed:
        assert r["rooted"], f"round {r['step']} not rooted: {r}"
        assert r["orphan_spans"] == 0
        # decomposition sums to the span by construction, and the span
        # agrees with the journaled round duration within the tolerance
        assert sum(r["phases_us"].values()) == pytest.approx(
            r["span_s"] * 1e6, rel=1e-6)
        assert abs(r["span_s"] - r["round_s"]) <= max(
            critpath.CHECK_REL * r["round_s"], critpath.CHECK_ABS_S)
        assert r["critical_path"] and r["critical_host"] is not None
    assert critpath.check(doc) == []
    assert critpath.main([obs, "--journal", jpath, "--check"]) == 0


@pytest.mark.integration
def test_divergence_drill_names_first_forked_chunk(tmp_path):
    from repro.coord.supervisor import run_cluster

    root = str(tmp_path / "ckpt")
    rep = run_cluster(
        root=root, n_hosts=3, total_steps=4, ckpt_every=2,
        backend="thread", loop="numpy", deadline_s=180.0,
        corrupt_host=1, corrupt_at_step=3,
    )
    assert not rep.lockstep()  # the injection took
    named = [a for a in rep.alerts if a.get("kind") == "digest_divergence"]
    assert named, f"no divergence alert: {rep.alerts}"
    a = named[0]
    assert a.get("chunk") is not None and a.get("chunk_index") is not None
    assert a["step"] == 4
    assert f"first divergent chunk {a['chunk']}[{a['chunk_index']}]" \
        in a["message"]
    # hosts 0 and 2 still agree, so the minority vote names the culprit
    assert a.get("host") == 1


@pytest.mark.integration
def test_kill_replay_drill_orphans_and_reattach(tmp_path):
    """SIGKILL the proxy mid-window: the respawned incarnation re-attaches
    to the same round tree; a window that never reaches its boundary
    (its root span never emitted) is left as an orphan subtree."""
    from repro.proxy import ProxyRunner

    obs = str(tmp_path / "obs")
    trace.enable(obs, "app", run_id="drill")
    spec = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}
    r = ProxyRunner(spec, chunk_bytes=1 << 10, max_restarts=2)
    r.start()
    try:
        window = trace.span_context(trace.round_trace_id(4))
        r.trace_ctx = window
        for s in range(1, 3):
            r.step(s)
        r.sync_state()  # drain the pipelined steps before the SIGKILL
        r.kill()
        for s in range(3, 5):
            r.step(s)  # death detected -> respawn re-attaches, replays
        r.sync_state()
        # the boundary: the window root span materializes
        tr = trace.get()
        tr.begin("worker.round", step=4, host=0, **trace.ctx_args(window))
        tr.end("worker.round")
        # second window: steps traced, but SIGKILL-style no boundary is
        # ever reached, so its root span never lands in any shard
        r.trace_ctx = trace.span_context(trace.round_trace_id(8))
        for s in range(5, 7):
            r.step(s)
        r.sync_state()
    finally:
        r.close()
    trace.disable()

    events, _ = report.load_shards(obs)
    spans = critpath.build_spans(events)
    per_trace = {}
    for s in spans:
        if s["trace"] is not None:
            per_trace.setdefault(s["trace"], []).append(s)

    done = per_trace["round:4"]
    ids = {s["span"] for s in done}
    parent_of = {s["span"]: s.get("parent") for s in done}
    assert all(critpath._resolves(s, parent_of, ids) for s in done)
    # the respawned incarnation's replayed + live steps joined the tree
    incs = {s["args"].get("inc") for s in done if s["name"] == "proxy.step"}
    assert incs == {0, 1}
    # ... and announced the re-attach on its REGISTER frame
    assert any(s["name"] == "proxy.register" for s in done)

    # the boundary-less window is one whole orphan subtree
    lost = per_trace["round:8"]
    ids8 = {s["span"] for s in lost}
    parent8 = {s["span"]: s.get("parent") for s in lost}
    assert lost and not any(
        critpath._resolves(s, parent8, ids8) for s in lost
    )
