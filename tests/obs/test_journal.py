"""CLUSTER_LOG.jsonl schema round-trip — every record kind the
coordinator writes parses back into its typed dataclass."""
import json

from repro.obs import journal as j


def _roundtrip(tmp_path, event, **fields):
    path = str(tmp_path / "CLUSTER_LOG.jsonl")
    w = j.JournalWriter(path)
    w.write(event, **fields)
    w.close()
    recs = j.read_journal(path)
    assert len(recs) == 1
    return recs[0]


def test_round_roundtrip(tmp_path):
    rec = _roundtrip(
        tmp_path, "round", step=6, status="committed", reason="",
        participants=[0, 1], acked=[0, 1], stragglers=[], commit_s=0.02,
        round_s=0.5, persist_s_max=0.3, bytes_written=4096,
        chunks_synced=4, chunks_clean=12, bytes_skipped=12288,
        sync_us=800.0, digest_us=0.0, fetch_us=120.0, stall_us=40.0,
    )
    assert isinstance(rec, j.RoundLine)
    assert rec.schema == j.JOURNAL_SCHEMA
    assert rec.committed and rec.step == 6 and rec.acked == [0, 1]
    assert rec.bytes_written == 4096 and rec.extra == {}
    assert rec.t > 0


def test_all_other_kinds_roundtrip(tmp_path):
    cases = {
        "join": dict(host=1, pid=4242, restored_from=3, latest_committed=3),
        "death": dict(host=2, reason="heartbeat", latest_committed=3),
        "finished": dict(host=0, step=9, digest="abc123"),
        "shutdown": dict(finished=[0, 1, 2]),
        "proxy_endpoint": dict(name="ph0", addr="127.0.0.1", port=7070),
        "proxy_placement": dict(worker=1, name="ph0", rescheduled=True),
        "proxy_host_death": dict(name="ph0", worker=1),
    }
    path = str(tmp_path / "CLUSTER_LOG.jsonl")
    w = j.JournalWriter(path)
    for event, fields in cases.items():
        w.write(event, **fields)
    w.close()
    recs = j.read_journal(path)
    assert [r.event for r in recs] == list(cases)
    for rec, (event, fields) in zip(recs, cases.items()):
        assert type(rec) is j.RECORD_TYPES[event]
        for k, v in fields.items():
            assert getattr(rec, k) == v, (event, k)
        assert rec.extra == {}


def test_unknown_event_and_fields_are_tolerated(tmp_path):
    path = str(tmp_path / "log.jsonl")
    w = j.JournalWriter(path)
    w.write("someday_event", payload=1)
    w.write("join", host=0, brand_new_field="v1.1")
    w.close()
    generic, join = j.read_journal(path)
    assert type(generic) is j.JournalRecord
    assert generic.extra["payload"] == 1
    assert isinstance(join, j.JoinLine) and join.host == 0
    assert join.extra == {"brand_new_field": "v1.1"}  # reader survives writer v1.1


def test_legacy_schemaless_and_torn_lines(tmp_path):
    path = str(tmp_path / "log.jsonl")
    with open(path, "w") as f:
        # pre-versioning line: no schema, no t
        f.write(json.dumps({"event": "death", "host": 1, "reason": "old"}) + "\n")
        f.write('{"event": "round", "step": 3, "stat')  # SIGKILL tail
    recs = j.read_journal(path)
    assert len(recs) == 1
    assert isinstance(recs[0], j.DeathLine)
    assert recs[0].schema == j.JOURNAL_SCHEMA  # legacy defaults to v1
    assert recs[0].reason == "old"


def test_rounds_helper(tmp_path):
    path = str(tmp_path / "log.jsonl")
    w = j.JournalWriter(path)
    w.write("join", host=0)
    w.write("round", step=2, status="committed")
    w.write("round", step=4, status="aborted", reason="death")
    w.close()
    rs = j.rounds(path)
    assert [r.step for r in rs] == [2, 4]
    assert [r.committed for r in rs] == [True, False]


def test_writer_never_raises_after_close(tmp_path):
    w = j.JournalWriter(str(tmp_path / "log.jsonl"))
    w.close()
    w.write("round", step=1)  # EBADF swallowed
    w.close()                 # idempotent
