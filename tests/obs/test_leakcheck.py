"""LeakCheck unit tests (Linux-only where /proc is required)."""
import os

import pytest

from repro.obs.leakcheck import LeakCheck, ResourceSnapshot

needs_proc = pytest.mark.skipif(
    not os.path.isdir("/proc/self/fd"), reason="needs /proc (Linux)"
)


@needs_proc
def test_clean_region_passes(tmp_path):
    with LeakCheck():
        with open(tmp_path / "f", "w") as f:
            f.write("x")  # opened AND closed inside: no growth


@needs_proc
def test_fd_leak_detected_and_named(tmp_path):
    lc = LeakCheck().start()
    leaked = open(tmp_path / "leaky", "w")  # noqa: SIM115
    try:
        with pytest.raises(AssertionError, match="leaky"):
            lc.assert_no_growth("unit")
        d = lc.diff()
        assert d["fd_growth"] >= 1
        assert any("leaky" in s for s in d["new_fds"])
    finally:
        leaked.close()


@needs_proc
def test_tolerance_allows_jitter(tmp_path):
    lc = LeakCheck(tolerance=1).start()
    leaked = open(tmp_path / "one", "w")  # noqa: SIM115
    try:
        lc.stop()
        lc.assert_no_growth()  # 1 fd <= tolerance 1
    finally:
        leaked.close()


@needs_proc
def test_exception_passthrough_skips_assert():
    # a failing drill must surface ITS error, not a secondary leak report
    with pytest.raises(RuntimeError, match="drill failed"):
        with LeakCheck():
            f = open("/dev/null")  # noqa: SIM115
            try:
                raise RuntimeError("drill failed")
            finally:
                f.close()


def test_unsupported_platform_degrades_to_noop(monkeypatch):
    import repro.obs.leakcheck as lk

    monkeypatch.setattr(lk, "_FD_DIR", "/nonexistent-proc/fd")
    monkeypatch.setattr(lk, "_SHM_DIR", "/nonexistent-shm")
    snap = ResourceSnapshot.capture()
    assert not snap.supported
    with LeakCheck():
        pass  # no false failure without /proc
