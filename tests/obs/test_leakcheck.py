"""LeakCheck unit tests (Linux-only where /proc is required)."""
import os

import pytest

from repro.obs.leakcheck import LeakCheck, ResourceSnapshot

needs_proc = pytest.mark.skipif(
    not os.path.isdir("/proc/self/fd"), reason="needs /proc (Linux)"
)


@needs_proc
def test_clean_region_passes(tmp_path):
    with LeakCheck():
        with open(tmp_path / "f", "w") as f:
            f.write("x")  # opened AND closed inside: no growth


@needs_proc
def test_fd_leak_detected_and_named(tmp_path):
    lc = LeakCheck().start()
    leaked = open(tmp_path / "leaky", "w")  # noqa: SIM115
    try:
        with pytest.raises(AssertionError, match="leaky"):
            lc.assert_no_growth("unit")
        d = lc.diff()
        assert d["fd_growth"] >= 1
        assert any("leaky" in s for s in d["new_fds"])
    finally:
        leaked.close()


@needs_proc
def test_tolerance_allows_jitter(tmp_path):
    lc = LeakCheck(tolerance=1).start()
    leaked = open(tmp_path / "one", "w")  # noqa: SIM115
    try:
        lc.stop()
        lc.assert_no_growth()  # 1 fd <= tolerance 1
    finally:
        leaked.close()


@needs_proc
def test_exception_passthrough_skips_assert():
    # a failing drill must surface ITS error, not a secondary leak report
    with pytest.raises(RuntimeError, match="drill failed"):
        with LeakCheck():
            f = open("/dev/null")  # noqa: SIM115
            try:
                raise RuntimeError("drill failed")
            finally:
                f.close()


def test_unsupported_platform_degrades_to_noop(monkeypatch):
    import repro.obs.leakcheck as lk

    monkeypatch.setattr(lk, "_FD_DIR", "/nonexistent-proc/fd")
    monkeypatch.setattr(lk, "_SHM_DIR", "/nonexistent-shm")
    snap = ResourceSnapshot.capture()
    assert not snap.supported
    with LeakCheck():
        pass  # no false failure without /proc


# -- obs-owned fd exclusion (the watchdog's trend sampler) -------------------

def test_is_obs_fd_patterns():
    from repro.obs.leakcheck import _is_obs_fd

    assert _is_obs_fd("/run/obs/trace-worker-123.jsonl")
    assert _is_obs_fd("/run/obs/metrics-app-9.json")
    assert _is_obs_fd("/run/CLUSTER_LOG.jsonl")
    assert _is_obs_fd("/run/obs/live_metrics.json.tmp")
    assert _is_obs_fd("/run/obs/merged.trace.json")
    assert _is_obs_fd("/run/obs/trace-app-1.jsonl (deleted)")
    assert not _is_obs_fd("/ckpt/step-3/data-h0000.bin")
    assert not _is_obs_fd("socket:[123456]")
    assert not _is_obs_fd("/dev/shm/crum-arena-1")


@needs_proc
def test_sample_exclude_obs_counts_and_excludes(tmp_path):
    from repro.obs.leakcheck import sample, watchdog_sample

    held = open(tmp_path / "trace-app-4242.jsonl", "w")  # noqa: SIM115
    data = open(tmp_path / "data-h0000.bin", "w")  # noqa: SIM115
    try:
        s = sample(exclude_obs=True)
        assert s["supported"] and s["fd_obs"] >= 1
        # the obs fd is excluded from the trend-facing count
        assert s["fd"] >= 1
        w = watchdog_sample()
        assert "fd_obs" in w  # the watchdog default is the excluding one
    finally:
        held.close()
        data.close()
    # plain sample() keeps the legacy shape: no fd_obs key
    assert "fd_obs" not in sample()
