"""Live telemetry plane: piggyback deltas, bounded store, defensive ingest."""
import json
import os

from repro.obs import metrics as obs_metrics
from repro.obs.live import (
    DEFAULT_RING,
    MAX_METRICS_PER_HOST,
    HeartbeatPiggyback,
    LiveAggregator,
    SeriesStore,
    read_snapshot,
)


# -- SeriesStore -------------------------------------------------------------

def test_ring_buffer_is_bounded():
    st = SeriesStore(ring=4)
    for i in range(100):
        st.append(0, "m", float(i), float(i))
    pts = st.series(0, "m")
    assert len(pts) == 4
    assert [v for _, v in pts] == [96.0, 97.0, 98.0, 99.0]
    assert st.latest(0, "m") == 99.0


def test_per_host_metric_budget():
    st = SeriesStore(ring=4)
    for i in range(MAX_METRICS_PER_HOST):
        assert st.append(0, f"m{i}", 0.0, 1.0)
    assert not st.append(0, "one_too_many", 0.0, 1.0)
    # other hosts have their own budget
    assert st.append(1, "m0", 0.0, 1.0)
    st.drop_host(0)
    assert st.append(0, "fresh_after_drop", 0.0, 1.0)


def test_snapshot_shape_is_json_ready():
    st = SeriesStore()
    st.append(0, "a", 1.5, 2.0)
    st.append(3, "b", 2.5, 4.0)
    snap = st.snapshot()
    json.dumps(snap)  # must not raise
    assert snap["0"]["a"] == [[1.5, 2.0]]
    assert st.hosts() == [0, 3]
    assert st.metrics(0) == ["a"]


# -- HeartbeatPiggyback ------------------------------------------------------

def test_piggyback_delta_and_seq():
    reg = obs_metrics.Registry()
    pb = HeartbeatPiggyback(reg)
    reg.inc("x", 5)
    p1 = pb.collect()
    assert p1["seq"] == 1 and p1["counters"] == {"x": 5}
    reg.inc("x", 2)
    p2 = pb.collect()
    assert p2["seq"] == 2 and p2["counters"] == {"x": 2}
    # nothing new -> the heartbeat rides bare
    assert pb.collect() is None
    reg.set("g", 7.0)
    p3 = pb.collect()
    assert p3["seq"] == 3 and p3["gauges"] == {"g": 7.0}


def test_piggyback_first_collect_never_none():
    """An idle worker's first beat still announces itself (seq 1)."""
    pb = HeartbeatPiggyback(obs_metrics.Registry())
    p = pb.collect()
    assert p is not None and p["seq"] == 1


def test_piggyback_overflow_defers_not_drops():
    reg = obs_metrics.Registry()
    pb = HeartbeatPiggyback(reg, max_keys=3)
    for i in range(5):
        reg.inc(f"k{i}", i + 1)
    p1 = pb.collect()
    assert len(p1["counters"]) == 3
    p2 = pb.collect()
    # the two deferred keys ride the next beat with their FULL value
    assert set(p1["counters"]) | set(p2["counters"]) == {
        f"k{i}" for i in range(5)
    }
    merged = dict(p1["counters"])
    merged.update(p2["counters"])
    assert merged == {f"k{i}": i + 1 for i in range(5)}


def test_piggyback_rides_in_one_frame():
    """The ISSUE's syscall budget: metrics ride INSIDE the heartbeat's
    framed sendall — one send_frame call, not a second message."""
    import socket

    from repro.coord.protocol import Connection, recv_frame

    class CountingSock:
        def __init__(self, sock):
            self._sock = sock
            self.sends = []

        def sendall(self, data):
            self.sends.append(bytes(data))
            return self._sock.sendall(data)

        def __getattr__(self, name):
            return getattr(self._sock, name)

    a, b = socket.socketpair()
    reg = obs_metrics.Registry()
    reg.inc("x", 3)
    payload = HeartbeatPiggyback(reg).collect()

    wrapped = CountingSock(a)
    conn = Connection(wrapped)
    conn.send("HEARTBEAT", host=0, step=1, metrics=payload)
    assert len(wrapped.sends) == 1  # header + msgpack body in ONE syscall

    got = recv_frame(b)
    assert got["metrics"]["counters"] == {"x": 3}
    a.close(), b.close()


# -- LiveAggregator ----------------------------------------------------------

def test_ingest_accumulates_counter_totals():
    agg = LiveAggregator()
    assert agg.ingest(0, {"seq": 1, "counters": {"x": 5}, "gauges": {}}, t=1.0)
    assert agg.ingest(0, {"seq": 2, "counters": {"x": 2}, "gauges": {}}, t=2.0)
    assert agg.store.latest(0, "x") == 7.0  # running total, not the delta
    assert agg.ingested == 2


def test_ingest_is_idempotent_on_redelivery():
    """The heartbeat-retry path: the same delta applied twice must count
    once — seq dedup, not value heuristics."""
    agg = LiveAggregator()
    payload = {"seq": 1, "counters": {"x": 5}, "gauges": {"g": 1.0}}
    assert agg.ingest(0, payload, t=1.0)
    assert not agg.ingest(0, payload, t=1.1)  # redelivered: dropped
    assert not agg.ingest(0, dict(payload), t=1.2)  # copy too
    assert agg.store.latest(0, "x") == 5.0
    assert len(agg.store.series(0, "x")) == 1
    assert agg.dropped == 2


def test_ingest_reset_host_restarts_seq():
    agg = LiveAggregator()
    agg.ingest(0, {"seq": 5, "counters": {"x": 5}, "gauges": {}}, t=1.0)
    assert not agg.ingest(0, {"seq": 1, "counters": {"x": 1}, "gauges": {}},
                          t=2.0)
    agg.reset_host(0)  # re-JOIN: fresh incarnation restarts at seq 1
    assert agg.ingest(0, {"seq": 1, "counters": {"x": 1}, "gauges": {}},
                      t=3.0)
    # totals restart with the process: 1, not 6
    assert agg.store.latest(0, "x") == 1.0


def test_ingest_survives_garbage():
    agg = LiveAggregator()
    for garbage in (
        None,
        "nope",
        42,
        [],
        {},                                   # no seq
        {"seq": "one"},                       # wrong type
        {"seq": 0},                           # out of range
        {"seq": 1, "counters": "xx", "gauges": 3},
        {"seq": 2, "counters": {1: 2, "ok": "bad", "b": True}},
    ):
        agg.ingest(0, garbage, t=1.0)
    # seq 1 and 2 were consumed by the shape-valid frames; their junk
    # keys were all skipped
    assert agg.store.metrics(0) == []
    assert agg.ingested == 2  # the two with a valid seq applied (empty)
    assert agg.dropped == 6   # None is "no payload", not a drop


def test_snapshot_roundtrip(tmp_path):
    path = str(tmp_path / "live_metrics.json")
    agg = LiveAggregator(snapshot_path=path, snapshot_every_s=0.0)
    agg.ingest(0, {"seq": 1, "counters": {"x": 5}, "gauges": {}}, t=1.0)
    agg.observe(-1, "round_s", 0.5, t=2.0)
    assert agg.write_snapshot() == path
    doc = read_snapshot(path)
    assert doc["schema"] == "crum-live-metrics/1"
    assert doc["series"]["0"]["x"] == [[1.0, 5.0]]
    assert doc["series"]["-1"]["round_s"] == [[2.0, 0.5]]
    assert doc["hosts"] == [-1, 0]

    # torn/corrupt snapshot reads as None, not an exception
    with open(path, "w") as f:
        f.write('{"schema": "crum-li')
    assert read_snapshot(path) is None
    assert read_snapshot(str(tmp_path / "absent.json")) is None


def test_maybe_snapshot_rate_limited(tmp_path):
    path = str(tmp_path / "live.json")
    agg = LiveAggregator(snapshot_path=path, snapshot_every_s=3600.0)
    assert agg.maybe_snapshot(now=100.0) == path
    os.remove(path)
    assert agg.maybe_snapshot(now=101.0) is None  # inside the interval
    assert not os.path.exists(path)
    assert agg.maybe_snapshot(now=4000.0) == path


def test_default_ring_is_sane():
    assert DEFAULT_RING >= 60  # a few minutes at heartbeat cadence


# -- tiered rollups ----------------------------------------------------------

def test_rollup_buckets_fold_and_close():
    s = SeriesStore(ring=8, rollups=(10.0,))
    for i, v in enumerate([1.0, 5.0, 3.0]):
        s.append(0, "fd", 100.0 + i, v)     # all inside bucket 100
    s.append(0, "fd", 112.0, 9.0)           # bucket 110 opens, 100 closes
    pts = s.rollup(0, "fd", 10.0)
    # closed bucket: [t, last, min, max, n]; open bucket rides along
    assert pts == [[100.0, 3.0, 1.0, 5.0, 3], [110.0, 9.0, 9.0, 9.0, 1]]


def test_rollup_open_bucket_is_provisional():
    s = SeriesStore(rollups=(60.0,))
    s.append(1, "x", 30.0, 2.0)
    assert s.rollup(1, "x", 60.0) == [[0.0, 2.0, 2.0, 2.0, 1]]
    s.append(1, "x", 40.0, 7.0)
    assert s.rollup(1, "x", 60.0) == [[0.0, 7.0, 2.0, 7.0, 2]]


def test_rollup_outlives_the_raw_ring():
    """The whole point: a spike the wrapped raw ring forgot is still in
    the rollup's min/max envelope."""
    s = SeriesStore(ring=4, rollups=(10.0,))
    s.append(0, "fd", 100.0, 99.0)          # the spike
    for i in range(8):
        s.append(0, "fd", 111.0 + i, 1.0)   # wraps the 4-point raw ring
    raw = s.series(0, "fd")
    assert len(raw) == 4 and all(v == 1.0 for _, v in raw)
    [closed, _open] = s.rollup(0, "fd", 10.0)
    assert closed[3] == 99.0                # max survived the wrap


def test_rollup_ring_is_bounded():
    s = SeriesStore(rollups=(1.0,), rollup_ring=3)
    for i in range(10):
        s.append(0, "x", float(i), float(i))
    pts = s.rollup(0, "x", 1.0)
    assert len(pts) == 4  # 3 closed (ring) + 1 open
    assert pts[0][0] == 6.0


def test_aggregator_snapshot_carries_rollups():
    agg = LiveAggregator()
    agg.observe(-1, "coord_fd", 10.0, t=5.0)
    agg.observe(-1, "coord_fd", 12.0, t=25.0)
    doc = agg.snapshot()
    pts = doc["rollups"]["10"]["-1"]["coord_fd"]
    assert pts == [[0.0, 10.0, 10.0, 10.0, 1], [20.0, 12.0, 12.0, 12.0, 1]]
    # 60s tier folds both into one (still-open) bucket
    assert doc["rollups"]["60"]["-1"]["coord_fd"] == \
        [[0.0, 12.0, 10.0, 12.0, 2]]


def test_drop_host_drops_rollups():
    s = SeriesStore(rollups=(10.0,))
    s.append(3, "x", 5.0, 1.0)
    s.append(3, "x", 15.0, 2.0)
    s.drop_host(3)
    assert s.rollup(3, "x", 10.0) == []
