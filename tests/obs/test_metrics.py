"""Registry unit tests + the canonical absorption helpers."""
import json
from types import SimpleNamespace

from repro.obs import metrics as m
from repro.obs import trace


def test_counters_gauges_hists():
    r = m.Registry()
    r.inc("a")
    r.inc("a", 4)
    r.set("g", 10)
    r.set("g", 3)  # latest wins
    for v in range(100):
        r.observe("h", v)
    assert r.counters_snapshot() == {"a": 5}
    snap = r.snapshot()
    assert snap["schema"] == m.METRICS_SCHEMA
    assert snap["gauges"] == {"g": 3}
    h = snap["hists"]["h"]
    assert h["count"] == 100 and h["max"] == 99
    assert 45 <= h["p50"] <= 55 and h["p99"] >= 95


def test_hist_decimation_bounds_memory():
    r = m.Registry()
    for v in range(m._HIST_CAP * 3):
        r.observe("h", v)
    assert len(r._hists["h"]) < m._HIST_CAP
    # the spread survives decimation: max is recent, p50 mid-range
    s = r.hist_summary("h")
    assert s["max"] >= m._HIST_CAP * 3 - 2


def test_counter_delta_and_merge():
    before = {"x": 5, "y": 2}
    after = {"x": 9, "y": 2, "z": 1}
    d = m.counter_delta(before, after)
    assert d == {"x": 4, "z": 1}  # unchanged keys dropped
    r = m.Registry()
    r.inc("x", 100)
    r.merge_counters(d)
    assert r.counters_snapshot() == {"x": 104, "z": 1}


def test_absorb_sync_info_nested():
    r = m.Registry()
    m.absorb_sync_info(
        {
            "step": 5,
            "chunks_synced": 3,
            "bytes_synced": 3000,
            "stall_us": 120.0,
            "wire_bytes": 900,
            "raw_bytes": 3000,
            "phase_us": {"digest": 40.0, "fetch": 60.0},
            "paging": {"faults": 7, "evictions": 2},
            "transport": {"wire_tx": 900, "transport": "stream"},
        },
        r,
    )
    c, g = r.counters_snapshot(), r.snapshot()["gauges"]
    assert c["proxy_syncs_total"] == 1
    assert c["proxy_chunks_synced"] == 3
    assert c["proxy_bytes_synced"] == 3000
    assert g["proxy_wire_bytes"] == 900
    assert g["uvm_faults"] == 7         # nested paging absorbed
    assert g["transport_wire_tx"] == 900
    assert "transport_transport" not in g  # non-numeric dropped
    assert r.hist_summary("proxy_sync_stall_us")["count"] == 1
    assert r.hist_summary("proxy_phase_digest_us")["count"] == 1


def test_absorb_checkpoint_result():
    r = m.Registry()
    res = SimpleNamespace(
        step=4, error=None, bytes_written=100, chunks_written=2,
        chunks_reused=8, chunks_synced=2, chunks_clean=8, bytes_skipped=800,
        blocking_s=0.01, persist_s=0.2, sync_us=50.0, digest_us=None,
        fetch_us=10.0, stall_us=0.0,
    )
    m.absorb_checkpoint_result(res, r)
    m.absorb_checkpoint_result(res, r)
    c = r.counters_snapshot()
    assert c["ckpt_checkpoints_total"] == 2
    assert "ckpt_errors_total" not in c
    assert c["ckpt_bytes_written"] == 200
    assert r.hist_summary("ckpt_persist_s")["count"] == 2
    m.absorb_checkpoint_result(SimpleNamespace(error="boom"), r)
    assert r.counters_snapshot()["ckpt_errors_total"] == 1


def test_absorb_round():
    r = m.Registry()
    m.absorb_round({"status": "committed", "commit_s": 0.01,
                    "bytes_written": 500}, r)
    m.absorb_round({"status": "aborted", "reason": "death"}, r)
    c = r.counters_snapshot()
    assert c["coord_rounds_total"] == 2
    assert c["coord_rounds_committed"] == 1
    assert c["coord_rounds_aborted"] == 1
    assert c["coord_bytes_written"] == 500


def test_dump_if_enabled(tmp_path):
    r = m.Registry()
    r.inc("k", 3)
    assert m.dump_if_enabled("proc", r) is None  # tracing off -> no dump
    trace.enable(str(tmp_path), "proc", set_env=False)
    path = m.dump_if_enabled("proc", r)
    assert path is not None
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == m.METRICS_SCHEMA
    assert doc["process"] == "proc"
    assert doc["counters"] == {"k": 3}
