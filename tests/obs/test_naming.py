"""Cross-layer naming regression pins.

The key sets frozen in ``repro.obs.metrics`` are consumed across layer
boundaries — benchmarks/gate.py reads gate-row fields, RoundRecord's
shape IS the journal ``round`` line, SYNCED info dicts cross the proxy
control plane. A producer renaming a key without updating the pin (and
every consumer) is a cross-layer break; these tests make it loud.
"""
import dataclasses

from repro.obs import metrics as m


def test_paging_stat_keys_pin():
    from repro.uvm.pager import PagingStats

    assert set(PagingStats().as_dict()) == set(m.PAGING_STAT_KEYS)


def test_paging_canonical_is_registry_form():
    from repro.uvm.pager import PagingStats

    canon = PagingStats().canonical()
    assert set(canon) == {f"uvm_{k}" for k in m.PAGING_STAT_KEYS}
    # canonical() and absorb_paging agree on the naming scheme
    r = m.Registry()
    m.absorb_paging(PagingStats().as_dict(), r)
    assert set(r.snapshot()["gauges"]) == set(canon)


def test_transport_stat_keys_pin(tmp_path):
    import numpy as np

    from repro.remote.transport import make_transport

    t = make_transport(
        "stream", {"w": np.zeros(64, np.uint8)}, 64,
        workdir=str(tmp_path),
    )
    try:
        stats = t.stats()
        assert set(stats) == set(m.TRANSPORT_STAT_KEYS)
        canon = t.canonical_stats()
        # numeric keys only, transport_-prefixed; the 'transport' kind
        # label is a string and stays out of the registry form
        assert set(canon) == {
            f"transport_{k}" for k, v in stats.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        }
        assert "transport_transport" not in canon
    finally:
        t.close()


def test_round_record_keys_pin():
    from repro.coord.coordinator import RoundRecord

    assert {f.name for f in dataclasses.fields(RoundRecord)} == set(
        m.ROUND_RECORD_KEYS
    )


def test_round_journal_line_matches_pin():
    from repro.obs.journal import RoundLine

    line_fields = {
        f.name for f in dataclasses.fields(RoundLine)
    } - {"event", "t", "schema", "extra"}
    assert line_fields == set(m.ROUND_RECORD_KEYS)


def test_sync_info_keys_pin():
    """The SYNCED info vocabulary: produced by the proxy service, finished
    app-side by supervisor._finish_sync (which adds ``stall_us``). Every
    pinned name must still appear in the producing pair."""
    import inspect

    from repro.proxy import service, supervisor

    src = inspect.getsource(service) + inspect.getsource(supervisor)
    for key in m.SYNC_INFO_KEYS:
        assert f'"{key}"' in src, f"SYNCED info key {key!r} gone"


def test_gate_row_keys_pin():
    """benchmarks/gate.py reads exactly these row fields."""
    import inspect

    from benchmarks import gate

    src = inspect.getsource(gate)
    for key in m.GATE_ROW_KEYS:
        assert f"{key}" in src, f"gate consumes {key!r} but pin says so"
