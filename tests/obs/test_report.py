"""Reporter tests: shard merge, journal track, validation, summary."""
import json
import os

from repro.obs import report
from repro.obs.journal import JournalWriter


def _write_shard(run_dir, process, pid, events, torn_tail=False):
    path = os.path.join(run_dir, f"trace-{process}-{pid}.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "ts": 0, "args": {"name": f"{process}:{pid}"},
        }) + "\n")
        for ev in events:
            f.write(json.dumps({"pid": pid, "tid": 1, **ev}) + "\n")
        if torn_tail:
            f.write('{"name": "torn", "ph": "i", "ts"')  # SIGKILL mid-write
    return path


def _mk_run(tmp_path):
    run_dir = str(tmp_path / "obs")
    os.makedirs(run_dir)
    _write_shard(run_dir, "app", 100, [
        {"name": "app.step", "ph": "X", "ts": 1000, "dur": 500,
         "args": {"step": 1}},
        {"name": "app.step", "ph": "X", "ts": 2000, "dur": 700,
         "args": {"step": 2}},
        {"name": "app.sync_stall", "ph": "X", "ts": 2800, "dur": 300,
         "args": {"epoch": 1}},
    ], torn_tail=True)
    _write_shard(run_dir, "proxy", 200, [
        {"name": "proxy.step", "ph": "X", "ts": 1100, "dur": 400,
         "args": {"step": 1, "inc": 0}},
        {"name": "proxy.respawn", "ph": "B", "ts": 3000, "args": {}},
        {"name": "proxy.respawn", "ph": "E", "ts": 3900},
    ])
    with open(os.path.join(run_dir, "metrics-app-100.json"), "w") as f:
        json.dump({"process": "app", "counters": {"proxy_restarts": 1},
                   "gauges": {"uvm_faults": 6}}, f)
    with open(os.path.join(run_dir, "metrics-proxy-200.json"), "w") as f:
        json.dump({"process": "proxy", "counters": {"proxy_restarts": 0},
                   "gauges": {"uvm_faults": 4}}, f)
    w = JournalWriter(os.path.join(run_dir, "CLUSTER_LOG.jsonl"))
    w.write("round", step=2, status="committed", bytes_written=99)
    w.close()
    return run_dir


def test_merge_produces_perfetto_doc(tmp_path):
    run_dir = _mk_run(tmp_path)
    out, events, metrics = report.merge(run_dir)
    with open(out) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["schema"] == "crum-trace/1"
    assert len(doc["otherData"]["shards"]) == 2
    names = [e["name"] for e in doc["traceEvents"]]
    assert "app.step" in names and "proxy.step" in names
    # journal became instants on the synthetic track
    jevs = [e for e in doc["traceEvents"] if e["name"] == "journal.round"]
    assert jevs and jevs[0]["pid"] == report.JOURNAL_PID
    assert jevs[0]["args"]["bytes_written"] == 99
    # no leftover internal keys; events sorted by ts
    assert all("_shard" not in e for e in doc["traceEvents"])
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)
    # torn tail skipped, no "torn" event
    assert "torn" not in names


def test_metrics_merged_across_processes(tmp_path):
    run_dir = _mk_run(tmp_path)
    m = report.merge_metrics(run_dir)
    assert m["counters"]["proxy_restarts"] == 1
    assert m["gauges"]["uvm_faults"] == 10  # summed per process
    assert sorted(m["processes"]) == ["app", "proxy"]


def test_validate_catches_orphans_and_malformed():
    ok = [
        {"name": "a", "ph": "B", "ts": 1, "pid": 1, "tid": 1},
        {"name": "a", "ph": "E", "ts": 2, "pid": 1, "tid": 1},
        {"name": "x", "ph": "X", "ts": 1, "dur": 5, "pid": 1, "tid": 1},
    ]
    assert report.validate_events(ok) == []

    orphan_e = [{"name": "a", "ph": "E", "ts": 2, "pid": 1, "tid": 1}]
    assert any("orphaned E" in p for p in report.validate_events(orphan_e))

    unclosed_b = [{"name": "a", "ph": "B", "ts": 1, "pid": 1, "tid": 1}]
    assert any("unclosed B" in p for p in report.validate_events(unclosed_b))

    no_dur = [{"name": "x", "ph": "X", "ts": 1, "pid": 1, "tid": 1}]
    assert any("without numeric dur" in p
               for p in report.validate_events(no_dur))

    bad_ph = [{"name": "x", "ph": "Z", "ts": 1, "pid": 1, "tid": 1}]
    assert any("unknown phase" in p for p in report.validate_events(bad_ph))

    # nesting is PER (pid, tid): interleaved tracks don't false-positive
    two_tracks = [
        {"name": "a", "ph": "B", "ts": 1, "pid": 1, "tid": 1},
        {"name": "b", "ph": "B", "ts": 2, "pid": 2, "tid": 1},
        {"name": "a", "ph": "E", "ts": 3, "pid": 1, "tid": 1},
        {"name": "b", "ph": "E", "ts": 4, "pid": 2, "tid": 1},
    ]
    assert report.validate_events(two_tracks) == []


def test_summary_derives_ratios(tmp_path):
    run_dir = _mk_run(tmp_path)
    _, events, metrics = report.merge(run_dir)
    text = report.summarize(events, metrics)
    assert "app.step" in text and "p99_us" in text
    # stall ratio = 300 / (500 + 700)
    assert "stall_ratio" in text and "0.25" in text
    assert "uvm_faults_per_step" in text
    assert "proxy_restarts" in text


def test_missing_and_corrupt_metric_shards_named(tmp_path, capsys):
    """A SIGKILLed process leaves a trace shard but no metrics dump (or a
    torn one); the reporter proceeds and NAMES the gap instead of dying."""
    run_dir = _mk_run(tmp_path)
    # killed-worker signature: traced, but no metrics twin
    _write_shard(run_dir, "worker3", 333, [
        {"name": "app.step", "ph": "X", "ts": 100, "dur": 5, "args": {}},
    ])
    # torn metrics dump (SIGKILL mid-replace)
    with open(os.path.join(run_dir, "metrics-worker4-444.json"), "w") as f:
        f.write('{"process": "worker4", "counters": {"x"')
    m = report.merge_metrics(run_dir)
    assert m["missing_metrics"] == ["worker3-333"]
    assert m["corrupt_metrics"] == ["metrics-worker4-444.json"]
    # surviving shards still merged
    assert m["counters"]["proxy_restarts"] == 1
    # gaps surface in the text summary and --check still passes
    _, events, metrics = report.merge(run_dir)
    text = report.summarize(events, metrics)
    assert "MISSING metric shards" in text and "worker3-333" in text
    assert "CORRUPT metric shards" in text
    assert report.main([run_dir, "--check"]) == 0


def test_summary_json_artifact(tmp_path):
    run_dir = _mk_run(tmp_path)
    out = os.path.join(run_dir, "summary.json")
    assert report.main([run_dir, "--summary-json", out]) == 0
    with open(out) as f:
        doc = json.load(f)
    assert doc["schema"] == "crum-obs-summary/1"
    assert doc["spans"]["app.step"]["count"] == 2
    assert doc["derived"]["stall_ratio"] == 0.25
    # proxy.step wins the step count (1 event); faults sum to 10
    assert doc["derived"]["uvm_faults_per_step"] == 10.0
    assert doc["counters"]["proxy_restarts"] == 1
    assert doc["missing_metrics"] == [] and doc["corrupt_metrics"] == []
    # the dict and the text come from one source
    text = report.summarize(*report.merge(run_dir)[1:])
    assert "stall_ratio" in text


def test_cli_check_mode(tmp_path, capsys):
    run_dir = _mk_run(tmp_path)
    assert report.main([run_dir, "--check"]) == 0
    out = capsys.readouterr().out
    assert "trace validation OK" in out
    assert os.path.exists(os.path.join(run_dir, "merged.trace.json"))

    # an invalid shard (unclosed B) must fail --check
    _write_shard(run_dir, "bad", 300, [
        {"name": "never.closed", "ph": "B", "ts": 1, "args": {}},
    ])
    assert report.main([run_dir, "--check"]) == 1

    assert report.main([str(tmp_path / "nope"), "--check"]) == 2
