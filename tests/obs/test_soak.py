"""Soak verdict engine: token matching, windows, the hard booleans."""
import json
import os

from repro.obs.journal import JOURNAL_SCHEMA, JournalWriter
from repro.obs.soak import (
    SOAK_SCHEMA,
    evidence_for,
    explain_alerts,
    load_inject_log,
    match_token,
    verdict,
)

T0 = 1000.0


def _write_run(tmp_path, injects, cluster_lines):
    """Lay out a minimal soak run dir from raw journal lines."""
    run_dir = str(tmp_path)
    os.makedirs(os.path.join(run_dir, "ckpt"), exist_ok=True)
    with open(os.path.join(run_dir, "INJECT_LOG.jsonl"), "w") as f:
        for doc in injects:
            f.write(json.dumps(
                {"schema": "crum-inject/1", "event": "inject", **doc}
            ) + "\n")
    with open(os.path.join(run_dir, "ckpt", "CLUSTER_LOG.jsonl"),
              "w") as f:
        for doc in cluster_lines:
            f.write(json.dumps(
                {"schema": JOURNAL_SCHEMA, **doc}) + "\n")
    return run_dir


def _inject(kind="kill_worker", t=T0, seq=1, host=0, any_=None, all_=None,
            explains=("worker_death", "round_abort"), window=30.0):
    return {"kind": kind, "target": f"host:{host}", "t": t, "seq": seq,
            "params": {"host": host},
            "expect": {"window_s": window, "host": host,
                       "any": list(any_ or []), "all": list(all_ or []),
                       "explains": list(explains)}}


def test_token_matching_and_windows(tmp_path):
    run_dir = _write_run(
        tmp_path,
        [_inject(any_=["alert:worker_death", "journal:death"])],
        [
            {"event": "death", "t": T0 + 1.0, "host": 0, "reason": "x"},
            # outside the 30s window: must not count
            {"event": "death", "t": T0 + 99.0, "host": 0, "reason": "x"},
            # wrong host for a host-pinned spec: must not count
            {"event": "alert", "t": T0 + 2.0, "kind": "worker_death",
             "severity": "warning", "host": 1, "message": ""},
        ],
    )
    [inj] = load_inject_log(run_dir)
    from repro.obs.journal import read_journal

    records = read_journal(
        os.path.join(run_dir, "ckpt", "CLUSTER_LOG.jsonl"))
    assert match_token("journal:death", inj, records) == \
        [f"death:host0@{T0 + 1.0:.3f}"]
    assert match_token("alert:worker_death", inj, records) == []
    assert evidence_for(inj, records)["evidenced"]  # "any" satisfied


def test_all_semantics_demand_every_token(tmp_path):
    run_dir = _write_run(
        tmp_path,
        [_inject(kind="disk_full",
                 all_=["journal:round_aborted_persist",
                       "journal:round_committed"],
                 explains=["round_abort"])],
        [{"event": "round", "t": T0 + 1.0, "step": 2, "status": "aborted",
          "reason": "host 0 persist failed: ENOSPC"}],
    )
    [inj] = load_inject_log(run_dir)
    from repro.obs.journal import read_journal

    records = read_journal(
        os.path.join(run_dir, "ckpt", "CLUSTER_LOG.jsonl"))
    assert not evidence_for(inj, records)["evidenced"]  # commit missing
    doc = verdict(run_dir)
    assert not doc["checks"]["all_injections_evidenced"]
    assert not doc["pass"]


def test_unexplained_alert_fails_the_run(tmp_path):
    run_dir = _write_run(
        tmp_path,
        [_inject(any_=["journal:death"])],
        [
            {"event": "death", "t": T0 + 1.0, "host": 0, "reason": "x"},
            {"event": "round", "t": T0 + 2.0, "step": 2,
             "status": "committed"},
            # an alert no injection claims
            {"event": "alert", "t": T0 + 3.0, "kind": "digest_divergence",
             "severity": "critical", "host": 1, "message": "forked"},
        ],
    )
    doc = verdict(run_dir)
    assert doc["checks"]["all_injections_evidenced"]
    assert not doc["checks"]["no_unexplained_alerts"]
    [a] = [x for x in doc["alerts"] if x["explained_by"] is None]
    assert a["kind"] == "digest_divergence"
    assert not doc["pass"]


def test_clean_run_passes(tmp_path):
    run_dir = _write_run(
        tmp_path,
        [_inject(any_=["journal:death"])],
        [
            {"event": "death", "t": T0 + 1.0, "host": 0, "reason": "x"},
            {"event": "alert", "t": T0 + 1.1, "kind": "worker_death",
             "severity": "warning", "host": 0, "message": "x"},
            {"event": "round", "t": T0 + 2.0, "step": 2,
             "status": "committed", "round_s": 1.0},
        ],
    )
    doc = verdict(run_dir)
    assert doc["schema"] == SOAK_SCHEMA
    assert doc["checks"] == {
        "all_injections_evidenced": True,
        "no_unexplained_alerts": True,
        "converged": True,
        "leaks_flat": True,
        "critpath_ok": True,
        "envelope_ok": True,
    }
    assert doc["pass"]


def test_explain_is_time_boxed():
    from repro.obs.journal import AlertLine, InjectLine

    inj = InjectLine(event="inject", t=T0, kind="kill_worker", seq=1,
                     expect={"window_s": 10.0,
                             "explains": ["worker_death"]})
    inside = AlertLine(event="alert", t=T0 + 5.0, kind="worker_death")
    outside = AlertLine(event="alert", t=T0 + 50.0, kind="worker_death")
    rows = explain_alerts([inj], [inside, outside])
    assert rows[0]["explained_by"] == 1
    assert rows[1]["explained_by"] is None


def test_envelope_and_leak_checks(tmp_path):
    run_dir = _write_run(
        tmp_path,
        [],
        [{"event": "round", "t": T0, "step": 2, "status": "committed",
          "round_s": 99.0}],
    )
    # a growing coord_fd rollup series (host -1) must trip leaks_flat
    obs_dir = os.path.join(run_dir, "obs")
    os.makedirs(obs_dir)
    with open(os.path.join(obs_dir, "live_metrics.json"), "w") as f:
        json.dump({
            "schema": "crum-live-metrics/1",
            "series": {},
            "rollups": {"10": {"-1": {
                "coord_fd": [[T0, 10, 10, 10, 3], [T0 + 10, 40, 10, 40, 3]],
            }}},
        }, f)
    doc = verdict(run_dir, round_envelope_s=30.0, fd_allowance=8)
    assert not doc["checks"]["envelope_ok"]
    assert doc["slow_rounds"] == [{"step": 2, "round_s": 99.0}]
    assert not doc["checks"]["leaks_flat"]
    assert doc["leak_growth"]["coord_fd"] == 30.0


def test_gate_soak_clean():
    from benchmarks.gate import soak_clean

    good = {"schema": "crum-soak/1", "n_injections": 3,
            "checks": {"a": True, "b": True}}
    assert soak_clean(good) == []
    bad = {"schema": "crum-soak/1", "n_injections": 3,
           "checks": {"a": True, "no_unexplained_alerts": False}}
    assert any("no_unexplained_alerts" in v for v in soak_clean(bad))
    assert soak_clean({"schema": "nope"})
    empty = {"schema": "crum-soak/1", "n_injections": 0,
             "checks": {"a": True}}
    assert any("zero injections" in v for v in soak_clean(empty))
