"""obs.top: pure rendering + run-dir mode + the METRICS endpoint."""
import json
import os

from repro.obs import top
from repro.obs.journal import JournalWriter
from repro.obs.live import LIVE_SCHEMA


def _snapshot():
    return {
        "schema": LIVE_SCHEMA,
        "t": 0.0,
        "hosts": [-1, 0, 1],
        "ingested": 12,
        "dropped": 1,
        "series": {
            "0": {
                "proxy_syncs_total": [[1.0, 2.0], [2.0, 4.0]],
                "uvm_faults": [[1.0, 30.0]],
                "something_else": [[1.0, 1.0]],
            },
            "1": {"proxy_syncs_total": [[1.0, 3.0]]},
            "-1": {"round_s": [[2.5, 0.4]]},
        },
    }


def test_render_table_and_rates():
    text = top.render(_snapshot(), [])
    assert "hosts=[-1, 0, 1]" in text
    assert "ingested=12" in text and "dropped=1" in text
    # per-host rows with the latest value; cumulative series get a rate
    assert "h0" in text and "h1" in text and "coord" in text
    assert "4/2s" in text          # (4-2)/(2-1) = 2/s on proxy_syncs_total
    assert "alerts: none" in text
    # something_else + coord's round_s summarized, not shown as columns
    assert "2 more series" in text


def test_render_alerts_and_empty_snapshot():
    alerts = [{"kind": "straggler", "severity": "warning", "host": 2,
               "step": 6, "message": "host 2 is slow"}]
    text = top.render(_snapshot(), alerts)
    assert "alerts (1):" in text and "straggler" in text
    text2 = top.render(None, [])
    assert "no live snapshot" in text2


def test_run_dir_mode_and_once(tmp_path, capsys):
    run_dir = str(tmp_path)
    with open(os.path.join(run_dir, "live_metrics.json"), "w") as f:
        json.dump(_snapshot(), f)
    w = JournalWriter(os.path.join(run_dir, "CLUSTER_LOG.jsonl"))
    w.write("alert", kind="worker_death", severity="warning", host=1,
            message="gone")
    w.write("round", step=3, status="committed")
    w.close()

    assert top.main(["--run-dir", run_dir, "--once"]) == 0
    out = capsys.readouterr().out
    assert "h0" in out
    assert "worker_death" in out  # journal alert surfaced

    # missing snapshot: renders the placeholder and exits non-zero
    assert top.main(["--run-dir", str(tmp_path / "void"), "--once"]) == 1


def test_endpoint_mode_against_live_coordinator(tmp_path):
    """The METRICS side channel answers any un-JOINed connection."""
    from repro.coord.coordinator import Coordinator

    coord = Coordinator(str(tmp_path / "root"), n_hosts=1).start()
    try:
        coord.live.ingest(
            0, {"seq": 1, "counters": {"proxy_syncs_total": 5}, "gauges": {}}
        )
        coord.watchdog.on_death(0, "test kick")
        host, port = coord.address
        # _on_metrics normally runs on the event loop; pump one dispatch
        import threading

        def pump():
            kind, conn, frame = coord._inbox.get(timeout=5)
            assert kind == "msg"
            coord._dispatch(conn, frame)

        t = threading.Thread(target=pump, daemon=True)
        t.start()
        snap, alerts = top.fetch_endpoint(host, port, timeout=5)
        t.join(timeout=5)
        assert snap["series"]["0"]["proxy_syncs_total"][0][1] == 5.0
        assert alerts and alerts[0]["kind"] == "worker_death"
        text = top.render(snap, alerts)
        assert "worker_death" in text
    finally:
        coord.close()
