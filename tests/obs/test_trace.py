"""Tracer unit tests: shard shape, enable semantics, fork safety."""
import json
import os
import time

import pytest

from repro.obs import trace


def _read_shard(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def test_disabled_by_default():
    assert trace.get() is None
    # module-level conveniences are no-ops, not crashes
    trace.instant("nobody.listens")
    trace.counter("nothing", x=1)


def test_shard_events_have_trace_event_shape(tmp_path):
    tr = trace.enable(str(tmp_path), "testproc", run_id="r1")
    tr.instant("ev.instant", step=3)
    t0 = time.perf_counter()
    time.sleep(0.01)
    tr.complete("ev.complete", t0, step=3, epoch=1)
    tr.begin("ev.span", step=3)
    tr.end("ev.span")
    tr.counter("ev.counter", faults=7)
    with tr.span("ev.ctx"):
        pass

    events = _read_shard(tr.path)
    # metadata line first: names the process track for Perfetto
    assert events[0]["ph"] == "M"
    assert events[0]["args"]["name"] == f"testproc:{os.getpid()}"
    assert events[0]["args"]["run"] == "r1"

    by_name = {}
    for ev in events[1:]:
        by_name.setdefault(ev["name"], []).append(ev)
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
        assert ev["pid"] == os.getpid()

    assert by_name["ev.instant"][0]["ph"] == "i"
    assert by_name["ev.instant"][0]["args"]["step"] == 3
    x = by_name["ev.complete"][0]
    assert x["ph"] == "X" and x["dur"] >= 10_000  # slept 10ms
    # back-dated: ts + dur lands ~now on the wall clock
    assert abs((x["ts"] + x["dur"]) - time.time_ns() // 1000) < 5_000_000
    assert [e["ph"] for e in by_name["ev.span"]] == ["B", "E"]
    assert [e["ph"] for e in by_name["ev.ctx"]] == ["B", "E"]
    assert by_name["ev.counter"][0]["ph"] == "C"


def test_enable_is_idempotent_first_wins(tmp_path):
    tr1 = trace.enable(str(tmp_path / "a"), "p1")
    tr2 = trace.enable(str(tmp_path / "b"), "p2")
    assert tr2 is tr1
    assert not os.path.exists(tmp_path / "b")


def test_enable_exports_env_and_children_pick_it_up(tmp_path, monkeypatch):
    tr = trace.enable(str(tmp_path), "launcher", run_id="runX")
    assert os.environ[trace.ENV_DIR] == tr.obs_dir
    assert os.environ[trace.ENV_RUN] == "runX"
    # simulate the child: fresh module state, same environment
    trace.TRACER = None
    child = trace.enable_from_env("worker0")
    assert child is not None
    assert child.obs_dir == tr.obs_dir
    assert child.run_id == "runX"
    assert "trace-worker0-" in os.path.basename(child.path)


def test_enable_from_env_without_env_is_noop():
    os.environ.pop(trace.ENV_DIR, None)
    assert trace.enable_from_env("worker") is None
    assert trace.get() is None


def test_disable_closes_and_clears(tmp_path):
    trace.enable(str(tmp_path), "p")
    trace.disable()
    assert trace.get() is None
    assert trace.ENV_DIR not in os.environ
    # re-enable works after disable
    tr = trace.enable(str(tmp_path), "p2", set_env=False)
    assert tr is trace.get()


@pytest.mark.skipif(not hasattr(os, "fork"), reason="needs fork")
def test_fork_child_reopens_own_shard(tmp_path):
    tr = trace.enable(str(tmp_path), "forky", set_env=False)
    tr.instant("parent.before")
    pid = os.fork()
    if pid == 0:  # child
        try:
            tr.instant("child.event")
            os._exit(0)
        except BaseException:
            os._exit(1)
    _, status = os.waitpid(pid, 0)
    assert os.waitstatus_to_exitcode(status) == 0
    child_shard = tmp_path / f"trace-forky-{pid}.jsonl"
    assert child_shard.exists()
    child_events = _read_shard(str(child_shard))
    assert [e["name"] for e in child_events] == ["process_name", "child.event"]
    assert all(e["pid"] == pid for e in child_events)
    # parent shard untouched by the child's writes
    names = [e["name"] for e in _read_shard(tr.path)]
    assert "child.event" not in names
