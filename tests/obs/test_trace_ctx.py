"""Causal-context API: id derivation, child minting, arg stamping."""
import json

from repro.obs import trace


def _shard_events(obs_dir):
    import glob

    events = []
    for path in glob.glob(f"{obs_dir}/trace-*.jsonl"):
        with open(path) as f:
            events.extend(json.loads(line) for line in f if line.strip())
    return events


def test_root_span_id_is_deterministic_and_63bit():
    a = trace.root_span_id(trace.round_trace_id(3))
    assert a == trace.root_span_id("round:3")  # pure function of the id
    assert a != trace.root_span_id("round:6")
    assert 0 < a < (1 << 63)
    assert a & 1  # never zero even under truncation


def test_new_span_id_range():
    ids = {trace.new_span_id() for _ in range(64)}
    assert len(ids) == 64
    assert all(0 < i < (1 << 63) and i & 1 for i in ids)


def test_span_context_and_child_derivation():
    root = trace.span_context("round:3", span=trace.root_span_id("round:3"))
    assert root["trace"] == "round:3"
    assert "parent" not in root  # the root has no remote parent
    child = trace.child_span(root)
    assert child["trace"] == "round:3"
    assert child["parent"] == root["span"]
    assert child["span"] != root["span"]
    # two frames from the same site get distinct receiver span ids
    assert trace.child_span(root)["span"] != child["span"]
    # off path: no ctx in, no ctx out
    assert trace.child_span(None) is None
    assert trace.child_span({}) is None


def test_ctx_args_shapes():
    assert trace.ctx_args(None) == {}
    assert trace.ctx_args({}) == {}
    full = {"trace": "round:3", "span": 5, "parent": 7}
    assert trace.ctx_args(full) == full
    assert trace.ctx_args({"trace": "t", "span": 5}) == {
        "trace": "t", "span": 5,
    }


def test_spans_carry_ctx_and_end_args_in_the_shard(tmp_path):
    obs = str(tmp_path / "obs")
    trace.enable(obs, "app", run_id="ctx")
    tr = trace.get()
    ctx = trace.span_context(trace.round_trace_id(9))
    tr.begin("worker.round", step=9, host=0, **trace.ctx_args(ctx))
    tr.end("worker.round", outcome="committed")
    tr.instant("coord.ack", **trace.ctx_args(trace.child_span(ctx)))
    trace.disable()

    events = _shard_events(obs)
    b = next(e for e in events if e.get("ph") == "B")
    assert b["args"]["trace"] == "round:9"
    assert b["args"]["span"] == ctx["span"]
    e = next(ev for ev in events if ev.get("ph") == "E")
    assert e["args"]["outcome"] == "committed"  # end() forwards args
    i = next(ev for ev in events if ev.get("ph") == "i")
    assert i["args"]["parent"] == ctx["span"]
