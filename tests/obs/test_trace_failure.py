"""Trace correctness under failure (``integration``-marked).

The kill -> replay drill with tracing ON: after SIGKILLing the proxy
mid-training, the merged trace must tell the story — the app-side
proxy-death instant, the respawn span, replayed steps tagged with the
*new* incarnation — and every shard must still be structurally valid
(balanced B/E nesting, parseable lines) despite the SIGKILL tearing the
dead proxy's shard mid-write.
"""
import json
import os

import pytest

from repro.obs import report, trace
from repro.proxy import ProxyRunner

pytestmark = pytest.mark.integration

SPEC = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}


def test_kill_replay_drill_leaves_a_valid_correlated_trace(tmp_path):
    obs_dir = str(tmp_path / "obs")
    trace.enable(obs_dir, "app", run_id="drill")

    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_restarts=2)
    r.start()
    try:
        for s in range(1, 5):
            r.step(s)
        _, info = r.sync_state()
        assert info["step"] == 4
        killed_pid = r.kill()
        for s in range(5, 9):
            r.step(s)  # death detected here -> respawn + replay
        _, info = r.sync_state()
        assert r.restarts == 1 and info["step"] == 8
    finally:
        r.close()
    from repro.obs.metrics import dump_if_enabled

    dump_if_enabled("app")

    # two proxy shards: the killed incarnation's and the respawn's
    shard_events, shards = report.load_shards(obs_dir)
    proxy_shards = [s for s in shards if "trace-proxy-" in s]
    assert len(proxy_shards) == 2
    assert any(f"-{killed_pid}.jsonl" in s for s in proxy_shards)

    by_name = {}
    for ev in shard_events:
        by_name.setdefault(ev.get("name"), []).append(ev)

    # 1. the app saw the death
    died = by_name["proxy.died"]
    assert died and died[0]["ph"] == "i"

    # 2. ... and spent a respawn span recovering from the synced step
    respawn = by_name["proxy.respawn"]
    assert [e["ph"] for e in respawn] == ["B", "E"]
    assert respawn[0]["args"]["resumed_from"] == 4
    replayed = by_name["proxy.replayed"][0]
    assert replayed["args"]["inc"] == 1

    # 3. replayed steps carry the new incarnation tag; pre-kill steps
    #    carry the old one
    incs = {ev["args"]["inc"] for ev in by_name["proxy.step"]}
    assert incs == {0, 1}
    inc1_steps = {ev["args"]["step"] for ev in by_name["proxy.step"]
                  if ev["args"]["inc"] == 1}
    assert {5, 6, 7, 8} <= inc1_steps

    # 4. every shard is structurally valid despite the SIGKILL
    assert report.validate_events(shard_events) == []

    # 5. the merged artifact is Perfetto-loadable and carries the
    #    proxy_restarts counter from the app's metrics snapshot
    out, events, metrics = report.merge(obs_dir)
    with open(out) as f:
        doc = json.load(f)
    assert doc["traceEvents"]
    assert metrics["counters"].get("proxy_restarts") == 1
    # correlation: every shard's metadata names the one run
    runs = {ev["args"].get("run") for ev in events
            if ev.get("ph") == "M" and "_shard" in ev}
    assert runs == {"drill"}
