"""SLO watchdog: every rule, severities, journal round-trip, abort hook."""
import os

import pytest

from repro.obs import journal
from repro.obs.watch import (
    ALERT_SCHEMA,
    SEV_CRITICAL,
    SEV_WARNING,
    Alert,
    WatchConfig,
    Watchdog,
)


def _round(step, status="committed", **kw):
    rec = {"step": step, "status": status, "round_s": 1.0, "stall_us": 0.0,
           "stragglers": [], "reason": ""}
    rec.update(kw)
    return rec


# -- rule by rule ------------------------------------------------------------

def test_happy_path_is_alert_free():
    wd = Watchdog(sampler=lambda: {"supported": True, "fd": 10, "shm": 2})
    for step in (3, 6, 9):
        for h in (0, 1):
            wd.on_heartbeat(h, step)
            wd.on_persist_done(h, step, "digest-same")
        wd.on_round(_round(step))
    for t in range(20):
        wd.tick(now=float(t * 10))
    assert wd.alerts == []
    assert wd.kinds() == set()


def test_stall_ratio_rule():
    wd = Watchdog(WatchConfig(stall_ratio_max=0.5))
    wd.on_round(_round(3, round_s=1.0, stall_us=600_000.0))
    [a] = wd.alerts
    assert a.kind == "stall_ratio" and a.severity == SEV_WARNING
    assert a.value == pytest.approx(0.6)
    assert a.limit == 0.5


def test_round_abort_then_abort_rate_critical():
    wd = Watchdog(WatchConfig(abort_rate_window=3))
    for i in range(3):
        wd.on_round(_round(3, status="aborted", reason=f"boom {i}"))
    kinds = [a.kind for a in wd.alerts]
    assert kinds.count("round_abort") == 3
    assert kinds.count("abort_rate") == 1
    assert wd.critical[0].kind == "abort_rate"
    # a commit resets the streak AND re-arms the critical
    wd.on_round(_round(6))
    wd.on_round(_round(9, status="aborted"))
    assert [a.kind for a in wd.alerts].count("abort_rate") == 1
    for _ in range(2):
        wd.on_round(_round(9, status="aborted"))
    assert [a.kind for a in wd.alerts].count("abort_rate") == 2


def test_straggler_rule():
    wd = Watchdog()
    wd.on_round(_round(3, stragglers=[2]))
    [a] = wd.alerts
    assert a.kind == "straggler" and a.host == 2 and a.step == 3


def test_heartbeat_skew_disabled_by_default():
    wd = Watchdog()
    wd.on_heartbeat(0, 100)
    wd.on_heartbeat(1, 1)
    assert wd.alerts == []


def test_heartbeat_skew_rule_with_rearm():
    wd = Watchdog(WatchConfig(max_step_skew=2))
    wd.on_heartbeat(0, 10)
    wd.on_heartbeat(1, 3)
    [a] = wd.alerts
    assert a.kind == "heartbeat_skew" and a.host == 1 and a.value == 7.0
    wd.on_heartbeat(1, 4)          # still lagging: no duplicate alert
    assert len(wd.alerts) == 1
    wd.on_heartbeat(1, 10)         # caught up: re-armed
    wd.on_heartbeat(0, 20)
    wd.on_heartbeat(1, 10)
    assert len(wd.alerts) == 2


def test_fault_rate_rule():
    wd = Watchdog(WatchConfig(fault_rate_max=100.0))
    wd.on_metric_point(0, "uvm_faults", 1.0, 0.0)
    wd.on_metric_point(0, "uvm_faults", 2.0, 50.0)    # 50/s: fine
    assert wd.alerts == []
    wd.on_metric_point(0, "uvm_faults", 3.0, 1000.0)  # 950/s: spike
    [a] = wd.alerts
    assert a.kind == "fault_rate" and a.host == 0
    # metrics outside the configured set never fire
    wd.on_metric_point(0, "proxy_syncs_total", 4.0, 1e9)
    assert len(wd.alerts) == 1


def test_leak_trend_rule_monotonic_only():
    feed = []
    wd = Watchdog(
        WatchConfig(leak_sample_every_s=0.0, leak_window=3,
                    fd_leak_allowance=2, shm_leak_allowance=1),
        sampler=lambda: feed.pop(0),
    )
    # transient burst that is reclaimed: NOT a leak
    for s in ({"supported": True, "fd": 10, "shm": 0},
              {"supported": True, "fd": 50, "shm": 0},
              {"supported": True, "fd": 10, "shm": 0}):
        feed.append(s)
        wd.tick(now=None)
    assert wd.alerts == []
    # steady climb past the allowance: the leak signature
    for i, s in enumerate(({"supported": True, "fd": 10, "shm": 0},
                           {"supported": True, "fd": 14, "shm": 0},
                           {"supported": True, "fd": 20, "shm": 0})):
        feed.append(s)
        wd.tick(now=None)
    assert "fd_leak_trend" in wd.kinds()


def test_digest_divergence_rule():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "aaaa")
    assert wd.alerts == []            # one host can't diverge
    wd.on_persist_done(1, 3, "bbbb")
    [a] = wd.alerts
    assert a.kind == "digest_divergence" and a.severity == SEV_CRITICAL
    assert a.step == 3
    wd.on_persist_done(2, 3, "cccc")  # same step: alerted once
    assert len(wd.alerts) == 1
    # a missing digest (old worker, inline loop without one) is ignored
    wd.on_persist_done(0, 6, None)
    wd.on_persist_done(1, 6, "")
    assert len(wd.alerts) == 1


def test_digest_state_cleared_at_commit():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "aaaa")
    wd.on_round(_round(3))            # commit settles the round
    wd.on_persist_done(1, 3, "bbbb")  # late/stale ack: fresh bookkeeping
    assert wd.alerts == []


def test_death_rules():
    wd = Watchdog()
    wd.on_heartbeat(0, 5)
    wd.on_death(0, "connection lost (worker death)")
    wd.on_proxy_host_death("ph0", worker=1)
    assert [a.kind for a in wd.alerts] == ["worker_death",
                                           "proxy_host_death"]
    assert all(a.severity == SEV_WARNING for a in wd.alerts)


# -- plumbing ----------------------------------------------------------------

def test_on_alert_callback_and_as_dict():
    got = []
    wd = Watchdog(on_alert=got.append)
    wd.on_death(2, "boom")
    assert got == wd.alerts
    d = got[0].as_dict()
    assert d["kind"] == "worker_death" and d["alert_schema"] == ALERT_SCHEMA
    assert "step" not in d  # Nones dropped from the wire/journal shape


def test_alert_journal_roundtrip(tmp_path):
    path = os.path.join(str(tmp_path), "CLUSTER_LOG.jsonl")
    w = journal.JournalWriter(path)
    alert = Alert("stall_ratio", SEV_WARNING, step=3, value=0.7, limit=0.5,
                  message="sync stall 0.7s vs round 1.0s")
    w.write("alert", **alert.as_dict())
    w.close()
    [line] = journal.alerts(path)
    assert isinstance(line, journal.AlertLine)
    assert line.kind == "stall_ratio" and line.severity == SEV_WARNING
    assert line.step == 3 and line.value == 0.7 and line.limit == 0.5
    assert line.alert_schema == ALERT_SCHEMA
    # typed reader filters alert lines out of a mixed journal
    w2 = journal.JournalWriter(path)
    w2.write("round", step=3, status="committed")
    w2.close()
    assert len(journal.alerts(path)) == 1
    assert len(journal.read_journal(path)) == 2


# -- divergence provenance ---------------------------------------------------

def test_divergence_names_first_forked_chunk_via_baseline():
    wd = Watchdog()
    good = {"b": [333], "w": [111, 222]}
    # a clean committed round records the per-chunk baseline
    wd.on_persist_done(0, 3, "same", chunk_digests=good)
    wd.on_persist_done(1, 3, "same", chunk_digests=good)
    wd.on_round(_round(3))
    assert wd.alerts == []
    # host 1 forks chunk w[1] at the next round
    wd.on_persist_done(0, 6, "aaaa", chunk_digests=good)
    wd.on_persist_done(1, 6, "bbbb",
                       chunk_digests={"b": [333], "w": [111, 999]})
    [a] = wd.alerts
    assert a.kind == "digest_divergence" and a.severity == SEV_CRITICAL
    assert a.chunk == "w" and a.chunk_index == 1
    assert a.host == 1  # named exactly: its digest left the baseline
    assert "first divergent chunk w[1] forked at step 6 on host 1" \
        in a.message


def test_divergence_minority_culprit_without_baseline():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "aaaa", chunk_digests={"w": [1, 2]})
    wd.on_persist_done(1, 3, "aaaa", chunk_digests={"w": [1, 2]})
    wd.on_persist_done(2, 3, "cccc", chunk_digests={"w": [1, 7]})
    [a] = wd.alerts
    assert a.chunk == "w" and a.chunk_index == 1
    assert a.host == 2  # outvoted 2:1 even with no committed baseline


def test_divergence_two_hosts_no_baseline_names_chunk_only():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "aaaa", chunk_digests={"w": [1]})
    wd.on_persist_done(1, 3, "bbbb", chunk_digests={"w": [9]})
    # a 1-vs-1 split is held back in case a further ack breaks the tie;
    # the round decision flushes it with the culprit unresolved
    assert wd.alerts == []
    wd.on_round(_round(3))
    [a] = wd.alerts
    assert a.kind == "digest_divergence"
    assert a.chunk == "w" and a.chunk_index == 0
    assert a.host is None  # 1v1 with no baseline: no culprit to name
    assert "an unidentified host" in a.message


def test_deferred_divergence_resolves_on_late_ack():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "aaaa", chunk_digests={"w": [1]})
    wd.on_persist_done(1, 3, "bbbb", chunk_digests={"w": [9]})
    assert wd.alerts == []  # held: culprit ambiguous at 1v1
    wd.on_persist_done(2, 3, "aaaa", chunk_digests={"w": [1]})
    [a] = wd.alerts  # the third ack outvotes host 1
    assert a.host == 1 and a.chunk == "w"


def test_divergence_without_chunk_tables_keeps_legacy_message():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "aaaa")
    wd.on_persist_done(1, 3, "bbbb")
    [a] = wd.alerts
    assert a.chunk is None and a.chunk_index is None
    assert "hosts disagree on state at step 3" in a.message
    d = a.as_dict()
    assert "chunk" not in d and "chunk_index" not in d  # Nones dropped


def test_chunk_state_popped_at_commit():
    wd = Watchdog()
    wd.on_persist_done(0, 3, "same", chunk_digests={"w": [1]})
    wd.on_persist_done(1, 3, "same", chunk_digests={"w": [1]})
    wd.on_round(_round(3))
    assert wd._chunks == {}
    assert wd._chunk_baseline == {("w", 0): 1}


def test_chunk_alert_journal_roundtrip(tmp_path):
    path = os.path.join(str(tmp_path), "CLUSTER_LOG.jsonl")
    w = journal.JournalWriter(path)
    alert = Alert("digest_divergence", SEV_CRITICAL, host=1, step=6,
                  chunk="w", chunk_index=3, message="forked")
    w.write("alert", **alert.as_dict())
    w.close()
    [line] = journal.alerts(path)
    assert line.chunk == "w" and line.chunk_index == 3 and line.host == 1


def test_default_sampler_excludes_obs_fds():
    from repro.obs import leakcheck

    wd = Watchdog()
    assert wd._sampler is leakcheck.watchdog_sample


def test_clock_skew_rule_rearms():
    """A skewed heartbeat wall clock alerts once, recovers, re-arms."""
    import time as _time

    wd = Watchdog(WatchConfig(max_clock_skew_s=5.0))
    wd.on_heartbeat(0, 1, wt=_time.time() + 60.0)
    [a] = wd.alerts
    assert a.kind == "clock_skew" and a.host == 0
    assert a.value > 5.0 and a.limit == 5.0
    # still skewed: no duplicate while the alert is armed
    wd.on_heartbeat(0, 2, wt=_time.time() + 60.0)
    assert len(wd.alerts) == 1
    # recovered: the rule re-arms…
    wd.on_heartbeat(0, 3, wt=_time.time())
    assert len(wd.alerts) == 1
    # …so a second skew window alerts again
    wd.on_heartbeat(0, 4, wt=_time.time() - 60.0)  # |skew| counts both ways
    assert [x.kind for x in wd.alerts] == ["clock_skew", "clock_skew"]


def test_clock_skew_disabled_by_default():
    import time as _time

    wd = Watchdog()
    wd.on_heartbeat(0, 1, wt=_time.time() + 1e6)
    wd.on_heartbeat(0, 2)  # wt-less heartbeats always fine
    assert wd.alerts == []


def test_tick_returns_the_leak_sample():
    seen = {"n": 0}

    def sampler():
        seen["n"] += 1
        return {"supported": True, "fd": 10 + seen["n"], "shm": 2}

    wd = Watchdog(WatchConfig(leak_sample_every_s=10.0), sampler=sampler)
    s = wd.tick(now=0.0)
    assert s == {"supported": True, "fd": 11, "shm": 2}
    assert wd.tick(now=1.0) is None  # inside the sampling interval
    assert wd.tick(now=20.0)["fd"] == 12
