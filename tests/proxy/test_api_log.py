"""API log: durable append/replay, torn tails, replay-plan selection."""
import os

from repro.proxy import ApiLog, iter_records


def test_append_read_roundtrip(tmp_path):
    p = str(tmp_path / "log.bin")
    log = ApiLog(p, truncate=True)
    recs = [
        {"call": "program", "spec": {"name": "numpy_sgd", "width": 8}},
        {"call": "register", "workdir": "/x", "layout": {"w": {"nbytes": 4}},
         "chunk_bytes": 1024},
        {"call": "upload", "step": 0, "paths": None},
        {"call": "step", "step": 1},
        {"call": "step", "step": 2},
        {"call": "sync", "step": 2, "digest": "abc"},
        {"call": "step", "step": 3},
    ]
    for r in recs:
        log.append(r)
    log.close()
    assert list(iter_records(p)) == recs


def test_replay_plan_selects_steps_after_last_sync(tmp_path):
    p = str(tmp_path / "log.bin")
    log = ApiLog(p, truncate=True)
    log.append({"call": "program", "spec": {"name": "numpy_sgd"}})
    log.append({"call": "register", "workdir": "/x", "layout": {},
                "chunk_bytes": 1024})
    for s in (1, 2, 3):
        log.append({"call": "step", "step": s})
    log.append({"call": "sync", "step": 3, "digest": "d3"})
    for s in (4, 5):
        log.append({"call": "step", "step": s})
    program, register, steps = log.replay_plan()
    assert program == {"name": "numpy_sgd"}
    assert register["chunk_bytes"] == 1024
    assert steps == [4, 5]
    assert log.last_synced_step() == 3
    log.close()


def test_replay_plan_upload_supersedes_earlier_steps(tmp_path):
    """A push (upload) onto a live runner captures device state just like
    a sync: steps issued before it must not replay on top of it."""
    p = str(tmp_path / "log.bin")
    log = ApiLog(p, truncate=True)
    log.append({"call": "program", "spec": {"name": "numpy_sgd"}})
    log.append({"call": "register", "workdir": "/x", "layout": {},
                "chunk_bytes": 1024})
    log.append({"call": "upload", "step": 0, "paths": None})
    for s in (1, 2):
        log.append({"call": "step", "step": s})
    log.append({"call": "upload", "step": 7, "paths": None})  # restore push
    log.append({"call": "step", "step": 8})
    _, _, steps = log.replay_plan()
    assert steps == [8]
    log.close()


def test_truncate_vs_append_mode(tmp_path):
    p = str(tmp_path / "log.bin")
    log = ApiLog(p, truncate=True)
    log.append({"call": "step", "step": 1})
    log.close()
    # append mode continues the existing log (a same-process reopen)
    log2 = ApiLog(p)
    log2.append({"call": "step", "step": 2})
    log2.close()
    assert [r["step"] for r in iter_records(p)] == [1, 2]
    # truncate starts a new incarnation's log
    log3 = ApiLog(p, truncate=True)
    log3.append({"call": "step", "step": 9})
    log3.close()
    assert [r["step"] for r in iter_records(p)] == [9]


def test_torn_tail_is_dropped_cleanly(tmp_path):
    p = str(tmp_path / "log.bin")
    log = ApiLog(p, truncate=True)
    log.append({"call": "step", "step": 1})
    log.append({"call": "step", "step": 2})
    log.close()
    size = os.path.getsize(p)
    with open(p, "r+b") as f:  # crash mid-append: half a record at the tail
        f.truncate(size - 3)
    assert [r["step"] for r in iter_records(p)] == [1]


def test_empty_and_missing_logs(tmp_path):
    missing = str(tmp_path / "nope.bin")
    assert list(iter_records(missing)) == []
    p = str(tmp_path / "empty.bin")
    ApiLog(p, truncate=True).close()
    assert list(iter_records(p)) == []
    assert ApiLog(p).last_synced_step() == 0
