"""Pipelined epoch syncs: the SYNC boundary overlaps the next steps.

The contract under test: ``sync_begin()`` issues SYNC{epoch} without a
barrier, the proxy executes it at exactly its position in the call stream
(so the captured image is the step-boundary state, regardless of how far
the app has run ahead), and the ack is matched asynchronously — including
across a SIGKILL, where replay re-issues the pending SYNC at the same
boundary and the ack is still collectable.
"""
import os
import signal

import pytest

from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest, tree_equal

pytestmark = pytest.mark.integration

SPEC = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}


def _inline_run(n_steps, spec=SPEC):
    prog = make_program(spec)
    s = prog.init_state()
    for step in range(1, n_steps + 1):
        s, _ = prog.step(s, step)
    return s


def test_epoch_sync_captures_boundary_while_app_runs_ahead():
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    r.start()
    try:
        for s in range(1, 6):
            r.step(s)
        epoch = r.sync_begin()
        for s in range(6, 11):
            r.step(s)  # the app is past the boundary before the ack lands
        state, info = r.sync_collect(epoch)
        assert info["epoch"] == epoch
        assert info["step"] == 5
        assert "stall_us" in info
        assert tree_equal(state, _inline_run(5))

        # and the barrier sync still sees the run-ahead steps
        state, info = r.sync_state()
        assert info["step"] == 10
        assert tree_equal(state, _inline_run(10))
    finally:
        r.close()


def test_epoch_sync_poll_is_nonblocking_and_eventually_lands():
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    r.start()
    try:
        for s in range(1, 4):
            r.step(s)
        epoch = r.sync_begin()
        res = None
        for _ in range(2000):
            res = r.sync_poll(epoch)
            if res is not None:
                break
        assert res is not None, "SYNCED never arrived via poll"
        state, info = res
        assert info["step"] == 3
        assert info["stall_us"] == 0.0
        assert tree_equal(state, _inline_run(3))
    finally:
        r.close()


def test_kill_with_inflight_epoch_sync_replays_bit_identical():
    """SIGKILL while an epoch SYNC is in flight: recovery re-issues the
    SYNC at its logged boundary, so the ack is still collectable and the
    boundary image is bit-identical — steps issued after the boundary
    replay too."""
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_restarts=2)
    r.start()
    try:
        for s in range(1, 6):
            r.step(s)
        epoch = r.sync_begin()
        for s in range(6, 9):
            r.step(s)
        os.kill(r.proxy.pid, signal.SIGKILL)
        for s in range(9, 11):
            r.step(s)  # death detected here -> respawn + replay
        state, info = r.sync_collect(epoch)
        assert r.restarts == 1
        assert info["step"] == 5
        assert tree_equal(state, _inline_run(5))

        final, info = r.sync_state()
        assert info["step"] == 10
        assert tree_equal(final, _inline_run(10))
        assert info["digest"] == tree_digest(_inline_run(10))
    finally:
        r.close()


def test_serialized_epochs_one_inflight_at_a_time():
    """A second sync_begin() while one epoch is pending collects the first
    implicitly — the data-plane table holds one boundary image at a time."""
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    r.start()
    try:
        r.step(1)
        e1 = r.sync_begin()
        r.step(2)
        e2 = r.sync_begin()
        assert e2 == e1 + 1
        assert list(r._pending_epochs) == [e2]  # e1 was drained
        state, info = r.sync_collect(e2)
        assert info["step"] == 2
        assert tree_equal(state, _inline_run(2))
        assert r.last_synced_step == 2
    finally:
        r.close()


def test_fused_digests_skip_boundary_scan():
    """fused_digests=True: the step program emits chunk digests with each
    step; the SYNC boundary consumes them instead of re-scanning — the
    boundary's digest time collapses to zero and the image stays exact."""
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, fused_digests=True)
    r.start()
    try:
        for s in range(1, 6):
            r.step(s)
        state, info = r.sync_state()
        assert tree_equal(state, _inline_run(5))
        phase = info["phase_us"]
        assert phase["prehashed_chunks"] > 0
        assert phase["digest"] == 0.0

        # second boundary: unchanged chunks are proven clean by the fused
        # digests alone (no scan), changed ones still move
        for s in range(6, 11):
            r.step(s)
        state, info = r.sync_state()
        assert tree_equal(state, _inline_run(10))
        assert info["phase_us"]["digest"] == 0.0
    finally:
        r.close()


def test_fused_digests_survive_kill_replay():
    ref = _inline_run(10)
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_restarts=2,
                    fused_digests=True)
    r.start()
    try:
        for s in range(1, 6):
            r.step(s)
        r.sync_state()
        r.kill()
        for s in range(6, 11):
            r.step(s)
        state, info = r.sync_state()
        assert r.restarts == 1
        assert info["step"] == 10
        assert tree_equal(state, ref)
        assert info["digest"] == tree_digest(ref)
    finally:
        r.close()
