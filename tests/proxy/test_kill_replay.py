"""The acceptance drill (CI smoke, ``integration``-marked): SIGKILL the
proxy mid-training -> supervisor respawns it, replays the API log, and the
final trained state is bit-identical to an uninterrupted run. Checkpoints
taken under ``device_runner=proxy`` restore correctly through BOTH persist
backends."""
import os
import signal

import numpy as np
import pytest

from repro.core import CheckpointedTrainer, CheckpointPolicy, RestoreManager
from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest, tree_equal

pytestmark = pytest.mark.integration

BACKENDS = ["thread"] + (["fork"] if hasattr(os, "fork") else [])
SPEC = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}


def _inline_run(n_steps, spec=SPEC):
    prog = make_program(spec)
    s = prog.init_state()
    for step in range(1, n_steps + 1):
        s, _ = prog.step(s, step)
    return s


def test_sigkill_mid_training_replays_bit_identical():
    ref = _inline_run(20)
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_restarts=2)
    r.start()
    try:
        for s in range(1, 9):
            r.step(s)
        _, info = r.sync_state()
        assert info["step"] == 8

        pid = r.kill()  # SIGKILL with steps about to be in flight
        assert pid is not None
        for s in range(9, 21):
            r.step(s)  # death detected here -> respawn + replay
        state, info = r.sync_state()

        assert r.restarts == 1
        assert r.recoveries and r.recoveries[0]["resumed_from_step"] == 8
        assert info["step"] == 20
        assert tree_equal(state, ref)
        assert info["digest"] == tree_digest(ref)
    finally:
        r.close()


def test_sigkill_detected_at_sync_replays_bit_identical():
    """Death between the last step and the sync barrier: the sync itself
    must detect it, recover, and return the correct state."""
    ref = _inline_run(10)
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_restarts=2, sync_timeout_s=60)
    r.start()
    try:
        for s in range(1, 11):
            r.step(s)
        r.proxy.flush()  # everything executed; now kill before SYNC
        os.kill(r.proxy.pid, signal.SIGKILL)
        state, info = r.sync_state()
        assert r.restarts == 1
        assert info["step"] == 10
        assert tree_equal(state, ref)
    finally:
        r.close()


def test_restart_budget_exhaustion_surfaces():
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_restarts=0)
    r.start()
    try:
        r.step(1)
        r.sync_state()
        r.kill()
        with pytest.raises(RuntimeError, match="giving up"):
            for s in range(2, 6):
                r.step(s)
            r.sync_state()
    finally:
        r.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_trainer_proxy_checkpoints_restore_through_backend(tmp_path, backend):
    """CheckpointedTrainer(device_runner='proxy'): checkpoints taken from
    the proxy's host mirror restore correctly (and restart resumes into a
    fresh proxy) over each persist backend."""
    root = str(tmp_path / f"ckpt-{backend}")
    ref = _inline_run(12)

    trainer = CheckpointedTrainer(
        None,
        store_root=root,
        policy=CheckpointPolicy(interval_steps=4),
        chunk_bytes=1 << 10,
        backend=backend,
        device_runner="proxy",
        program=SPEC,
    )

    def init_state():
        return {"device": None, "host": {"step": np.int64(0)}}

    state, start = trainer.resume_or(init_state)
    assert start == 0
    state = trainer.run(state, num_steps=8, start_step=0)
    trainer.finish()
    assert [r.step for r in trainer.results] == [4, 8]
    assert all(r.error is None for r in trainer.results)

    # restart: a fresh trainer restores step 8 and pushes it into a new proxy
    trainer2 = CheckpointedTrainer(
        None,
        store_root=root,
        policy=CheckpointPolicy(interval_steps=4),
        chunk_bytes=1 << 10,
        backend=backend,
        device_runner="proxy",
        program=SPEC,
    )
    state2, start2 = trainer2.resume_or(init_state)
    assert start2 == 8
    assert tree_equal(state2["device"], _inline_run(8))
    state2 = trainer2.run(state2, num_steps=4, start_step=8)
    trainer2.finish()
    assert tree_equal(state2["device"], ref)

    # and the persisted image itself round-trips
    restored, manifest = RestoreManager(trainer2.store).restore()
    assert manifest.step == 12
    assert tree_equal(restored["device"], ref)


def test_trainer_survives_proxy_kill_mid_run(tmp_path):
    """Kill the proxy in the middle of trainer.run(): training continues
    transparently and the final state matches the uninterrupted run."""
    root = str(tmp_path / "ckpt")
    ref = _inline_run(10)
    trainer = CheckpointedTrainer(
        None,
        store_root=root,
        policy=CheckpointPolicy(interval_steps=5),
        chunk_bytes=1 << 10,
        device_runner="proxy",
        program=SPEC,
    )
    state, _ = trainer.resume_or(lambda: {"device": None,
                                          "host": {"step": np.int64(0)}})
    state = trainer.run(state, num_steps=6, start_step=0)
    trainer.runner.kill()
    state = trainer.run(state, num_steps=4, start_step=6)
    trainer.finish()
    assert all(r.error is None for r in trainer.results)
    assert trainer.runner.restarts == 1
    assert tree_equal(state["device"], ref)
