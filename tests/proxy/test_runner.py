"""Device-proxy runner: proxied execution is bit-identical to inline,
pipelined calls flush correctly, and the RestoreManager proxy path replays
into a fresh proxy. Marked ``integration`` (spawns proxy OS processes)."""
import numpy as np
import pytest

from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest, tree_equal

pytestmark = pytest.mark.integration

SPEC = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}


def _inline_run(n_steps, spec=SPEC):
    prog = make_program(spec)
    s = prog.init_state()
    for step in range(1, n_steps + 1):
        s, _ = prog.step(s, step)
    return s


def test_proxied_run_bit_identical_to_inline():
    ref = _inline_run(12)
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    r.start()
    try:
        for s in range(1, 13):
            r.step(s)
        state, info = r.sync_state()
        assert info["step"] == 12
        assert tree_equal(state, ref)
        assert info["digest"] == tree_digest(ref)
        # the sync stats rode the data plane, not the control frame
        assert info["bytes_synced"] > 0
    finally:
        r.close()


def test_pipeline_auto_flush_watermark():
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, max_pipeline=4)
    r.start()
    try:
        for s in range(1, 10):
            r.step(s)
            assert r.proxy.inflight < 4  # watermark flushes keep it bounded
        state, info = r.sync_state()
        assert info["step"] == 9
        assert tree_equal(state, _inline_run(9))
    finally:
        r.close()


def test_sync_midway_then_continue():
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    r.start()
    try:
        for s in range(1, 6):
            r.step(s)
        mid, info = r.sync_state()
        assert tree_equal(mid, _inline_run(5))
        for s in range(6, 11):
            r.step(s)
        end, info = r.sync_state()
        assert tree_equal(end, _inline_run(10))
        # second sync only moves chunks that changed since the first
        assert info["chunks_synced"] > 0
    finally:
        r.close()


def test_push_overwrites_proxy_state():
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    r.start()
    try:
        for s in range(1, 4):
            r.step(s)
        r.sync_state()
        target = _inline_run(7)  # pretend this was restored from a checkpoint
        r.push(target)
        state, _ = r.sync_state()
        assert tree_equal(state, target)
        # stepping continues from the pushed state
        r.step(8)
        state, _ = r.sync_state()
        assert tree_equal(state, _inline_run(8))
    finally:
        r.close()


def test_restore_into_proxy_replays_checkpoint(tmp_store):
    """RestoreManager's proxy path: restore a committed image, start a
    fresh proxy from it, and training continues bit-identically."""
    from repro.core import ForkedCheckpointer, RestoreManager

    mid = _inline_run(6)
    ck = ForkedCheckpointer(tmp_store, chunk_bytes=1 << 10, digest_on_device=False)
    ck.save_async(6, {"device": mid, "host": {"step": np.int64(6)}}).wait()
    ck.close()

    r = ProxyRunner(SPEC, chunk_bytes=1 << 10)
    try:
        state, manifest = RestoreManager(tmp_store).restore_into_proxy(r)
        assert manifest.step == 6
        assert r.started
        assert tree_equal(state["device"], mid)
        for s in range(7, 11):
            r.step(s)
        end, info = r.sync_state()
        assert tree_equal(end, _inline_run(10))
    finally:
        r.close()
