"""Segment data plane: create/attach, cross-table visibility, shadow factory."""
import numpy as np
import pytest

from repro.core import ShadowStateManager
from repro.proxy import SegmentTable
from repro.utils.tree import tree_equal


def _state():
    return {
        "w": np.arange(1024, dtype=np.float32),
        "nested": {"b": np.ones((16,), np.float32),
                   "step": np.zeros((), np.int32)},
    }


def test_create_read_roundtrip(tmp_path):
    s = _state()
    t = SegmentTable.create(s, workdir=str(tmp_path))
    out = t.read_state()
    assert tree_equal(s, out)
    t.close()


def test_attach_sees_writes_from_creator(tmp_path):
    s = _state()
    creator = SegmentTable.create(s, workdir=str(tmp_path))
    attached = SegmentTable.attach(str(tmp_path), creator.layout)
    # attached view sees the initial bytes
    assert np.array_equal(
        attached.view("w").view(np.float32), np.arange(1024, dtype=np.float32)
    )
    # and later writes, without any message carrying the data
    s2 = dict(s)
    s2["w"] = s["w"] * 2
    creator.write_state(s2)
    assert np.array_equal(
        attached.view("w").view(np.float32), np.asarray(s2["w"])
    )
    attached.close()
    creator.close()


def test_write_state_rejects_shape_changes(tmp_path):
    s = _state()
    t = SegmentTable.create(s, workdir=str(tmp_path))
    bad = dict(s)
    bad["w"] = np.zeros(7, np.float32)
    with pytest.raises(ValueError, match="re-register"):
        t.write_state(bad)
    t.close()


def test_shadow_segment_factory_shares_pages(tmp_path):
    """Shadow buffers allocated through the factory ARE the segments: a
    shadow sync on one side is visible to a plain attach on the other."""
    s = {"w": np.arange(256, dtype=np.float32)}
    table = SegmentTable.create(s, workdir=str(tmp_path))
    sh = ShadowStateManager(
        chunk_bytes=256, digest_on_device=False, segment_factory=table.factory
    )
    sh.register(s)
    sh.sync(s)
    peer = SegmentTable.attach(str(tmp_path), table.layout)
    assert np.array_equal(peer.view("w").view(np.float32), s["w"])
    peer.close()
    table.close()


def test_factory_rejects_mismatched_sizes(tmp_path):
    s = {"w": np.arange(16, dtype=np.float32)}
    t = SegmentTable.create(s, workdir=str(tmp_path))
    with pytest.raises(ValueError):
        t.factory(("w", 0), 9999)
    with pytest.raises(ValueError):
        t.factory(("w", 1), 64)  # non-zero shard ordinal
    t.close()


def test_write_chunks_delta_and_bounds(tmp_path):
    s = {"w": np.arange(256, dtype=np.float32)}  # 1024B, 4 chunks of 256
    t = SegmentTable.create(s, workdir=str(tmp_path))
    base_bytes = t.bytes_written
    s2 = {"w": np.array(s["w"])}
    s2["w"][70] = -1.0  # chunk 1
    written = t.write_chunks(s2, {"w": [1]}, 256)
    assert written == 256
    assert t.bytes_written == base_bytes + 256
    got = t.view("w").view(np.float32)
    assert got[70] == -1.0
    assert np.array_equal(got[:64], s["w"][:64])  # chunk 0 untouched
    # malformed indices are rejected, never silently "written"
    with pytest.raises(IndexError):
        t.write_chunks(s2, {"w": [-1]}, 256)
    with pytest.raises(IndexError):
        t.write_chunks(s2, {"w": [4]}, 256)
    with pytest.raises(KeyError):
        t.write_chunks(s2, {"nope": [0]}, 256)
    t.close()
