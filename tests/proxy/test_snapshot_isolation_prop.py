"""Snapshot isolation under overlap — property-tested.

The invariant: for ANY interleaving of pipelined STEPs and epoch SYNCs,
every SYNCED{epoch} commits the image of exactly its step boundary —
never a torn mix of two steps, never a stale earlier boundary — no matter
how far the application ran ahead before collecting the ack.

The harness runs a real :class:`ProxyService` over an in-process
socketpair (no child process, so each example costs milliseconds) with
the streamed transport, so the data plane crosses the same CHUNKS-frame
machinery the cross-host path uses.

The property test proper needs Hypothesis (optional in this environment —
it skips cleanly when absent); a seeded-random version of the same
property always runs so CI exercises the invariant either way.
"""
import random
import socket
import threading

import pytest

from repro.coord.protocol import Connection
from repro.proxy import make_program
from repro.proxy.client import DeviceProxy
from repro.proxy.service import ProxyService
from repro.remote.transport import make_transport
from repro.utils.tree import tree_digest, tree_equal

SPEC = {"name": "numpy_sgd", "rows": 4, "width": 8, "seed": 0}
CHUNK = 1 << 8


def _inline_states(n_steps):
    """state after step k, for k = 0..n_steps (k=0: init)."""
    prog = make_program(SPEC)
    s = prog.init_state()
    out = [s]
    for step in range(1, n_steps + 1):
        s, _ = prog.step(s, step)
        out.append(s)
    return out


class _Harness:
    """ProxyService on a thread + DeviceProxy on a socketpair."""

    def __init__(self, fused_digests=False):
        a, b = socket.socketpair()
        a.settimeout(0.2)
        b.settimeout(0.2)
        self.svc = ProxyService(Connection(b))
        self.thread = threading.Thread(target=self.svc.serve, daemon=True)
        self.thread.start()
        # endpoint mode: alive() is "connection open", no child process
        self.dp = DeviceProxy(endpoint=("inproc", 0), op_timeout_s=30.0)
        self.dp.conn = Connection(a)
        self.dp.conn.settimeout(0.2)

        init = make_program(SPEC).init_state()
        self.transport = make_transport("stream", init, CHUNK)
        self.dp.on_data = self.transport.on_chunks
        self.dp.send_program(SPEC)
        self.dp.register(
            **self.transport.register_fields(),
            chunk_bytes=CHUNK,
            fused_digests=fused_digests,
        )
        self.dp.upload(step=0, payload_frames=self.transport.payload_frames(None))

    def close(self):
        self.dp.close(graceful=True)
        self.thread.join(timeout=10)
        self.transport.close(unlink=True)


def _check_interleaving(ops, fused_digests=False):
    """Run an op sequence ('step' | 'sync') and verify every committed
    image is the exact, untorn boundary state."""
    n_steps = sum(1 for op in ops if op == "step")
    refs = _inline_states(n_steps)
    h = _Harness(fused_digests=fused_digests)
    try:
        step = 0
        epoch = 0
        pending = []  # (epoch, boundary step), issued order
        for op in ops:
            if op == "step":
                step += 1
                h.dp.step(step)
            else:
                epoch += 1
                h.dp.sync_begin(epoch)
                pending.append((epoch, step))
        # acks arrive in issue order; collecting epoch k stops before
        # epoch k+1's CHUNKS frames, so the app table must hold exactly
        # boundary k's image at that moment — the isolation property
        for e, boundary in pending:
            msg = h.dp.collect_synced(e, timeout=30.0)
            assert msg["epoch"] == e
            assert msg["step"] == boundary
            assert msg["digest"] == tree_digest(refs[boundary])
            assert tree_equal(h.transport.read_state(), refs[boundary])
    finally:
        h.close()


_OPS_SMOKE = [
    ["sync"],
    ["step", "sync"],
    ["step", "sync", "step", "step", "sync", "step"],
    ["sync", "sync", "step", "sync", "sync"],
]


@pytest.mark.parametrize("ops", _OPS_SMOKE, ids=["-".join(o) for o in _OPS_SMOKE])
def test_snapshot_isolation_fixed_interleavings(ops):
    _check_interleaving(ops)


@pytest.mark.parametrize("seed", range(6))
def test_snapshot_isolation_random_interleavings(seed):
    rng = random.Random(seed)
    ops = [rng.choice(["step", "step", "sync"]) for _ in range(rng.randint(2, 14))]
    _check_interleaving(ops, fused_digests=bool(seed % 2))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dependency: the seeded tests above still run
    pass
else:

    @given(
        ops=st.lists(st.sampled_from(["step", "sync"]), min_size=1, max_size=16),
        fused=st.booleans(),
    )
    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_snapshot_isolation_property(ops, fused):
        _check_interleaving(ops, fused_digests=fused)
