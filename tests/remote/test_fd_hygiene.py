"""Resource hygiene on the reconnect path (``remote`` marker): every
ProxyDiedError branch closes its socket, so >= 20 kill/respawn cycles
leak no file descriptors or /dev/shm segments in the application
process. Audited through ``repro.obs.leakcheck`` so a failure names the
leaked fds (symlink targets), not just a count."""
import os

import pytest

from repro.obs.leakcheck import LeakCheck
from repro.proxy import ProxyRunner

pytestmark = pytest.mark.remote

SPEC = {"name": "numpy_sgd", "rows": 4, "width": 16, "seed": 0}
CYCLES = 22


@pytest.mark.skipif(not os.path.isdir("/proc/self/fd"),
                    reason="needs /proc (Linux)")
@pytest.mark.parametrize("transport", ["segment", "stream"])
def test_no_fd_leak_across_kill_respawn_cycles(transport):
    r = ProxyRunner(
        SPEC, chunk_bytes=1 << 10, transport=transport,
        max_restarts=CYCLES + 2, respawn_backoff_s=0.0,
    )
    r.start()
    try:
        step = 0
        for _ in range(3):  # settle allocations (mp plumbing, buffers)
            step += 1
            r.step(step)
        r.sync_state()
        # a couple of fds of jitter are tolerated (GC timing); a leak of
        # one fd per cycle would show up as >= CYCLES
        lc = LeakCheck(tolerance=4, shm_tolerance=0).start()
        for _ in range(CYCLES):
            r.kill()
            step += 1
            r.step(step)      # detects death -> respawn + replay
            r.sync_state()
        assert r.restarts == CYCLES
        lc.assert_no_growth(f"{CYCLES} kill/respawn cycles ({transport})")
    finally:
        r.close()


def test_recover_backoff_is_jittered(monkeypatch):
    """A respawn attempt that itself fails is retried after a *random*,
    exponentially widening backoff — never a fixed hammer interval."""
    from repro.remote.host import ProxyHostHandle
    import repro.proxy.supervisor as sup_mod

    windows = []
    monkeypatch.setattr(
        sup_mod.random, "uniform",
        lambda a, b: windows.append((a, b)) or 0.0,
    )

    daemons = [ProxyHostHandle(f"b-ph{i}").start() for i in range(2)]
    # after the first death: two DEAD endpoints, then the live survivor —
    # recovery attempts 1 and 2 fail, attempt 3 lands
    replacements = [("127.0.0.1", 1), ("127.0.0.1", 1), daemons[1].addr]
    current = [daemons[0].addr]

    def provider(failed=False):
        if failed:
            current[0] = replacements.pop(0)
        return current[0]

    r = ProxyRunner(
        SPEC, chunk_bytes=1 << 10, transport="stream", max_restarts=6,
        endpoint_provider=provider, respawn_backoff_s=0.05,
    )
    r.start()
    try:
        r.step(1)
        r.sync_state()
        daemons[0].kill()
        r.step(2)       # death detected -> recover through the dead pair
        _, info = r.sync_state()
        assert info["step"] == 2
        assert r.restarts == 3  # one per attempt (two dead + the landing)
        # backoff windows: full jitter from 0, cap widening per attempt
        assert len(windows) == 2  # attempt 0 never sleeps
        assert all(a == 0.0 for a, _ in windows)
        caps = [b for _, b in windows]
        assert caps == sorted(caps) and caps[0] < caps[-1]
    finally:
        r.close()
        for d in daemons:
            d.terminate()
