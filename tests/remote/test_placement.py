"""Placement: the map's assignment policy + the coordinator handshake."""
import threading

import numpy as np
import pytest

from repro.coord.coordinator import Coordinator
from repro.coord.protocol import MSG_FINISHED, MSG_JOIN, MSG_WELCOME, connect
from repro.remote.placement import (
    PlacementMap,
    register_proxy_endpoint,
    request_proxy_endpoint,
)


# -- PlacementMap ---------------------------------------------------------------

def test_assign_sticky_and_least_loaded():
    pm = PlacementMap()
    pm.register("a", "127.0.0.1", 1)
    pm.register("b", "127.0.0.1", 2)
    e0 = pm.assign(0)
    e1 = pm.assign(1)
    assert {e0.name, e1.name} == {"a", "b"}  # spread, not piled
    assert pm.assign(0).name == e0.name      # sticky
    e2 = pm.assign(2)
    assert pm.loads()[e2.name] <= 2


def test_dead_endpoint_reassigns_to_survivor():
    pm = PlacementMap()
    pm.register("a", "127.0.0.1", 1)
    pm.register("b", "127.0.0.1", 2)
    first = pm.assign(0)
    pm.report_dead(first.name)
    second = pm.assign(0)
    assert second.name != first.name
    assert [w for w, _ in pm.history] == [0, 0]  # the audit trail


def test_exclude_and_exhaustion():
    pm = PlacementMap()
    pm.register("a", "127.0.0.1", 1)
    assert pm.assign(0, exclude=("a",)) is None
    # dead-marked endpoints are offered as a LAST resort ("dead" can be a
    # transient verdict; trying beats failing the worker outright) —
    # None only when everything is excluded
    pm.report_dead("a")
    assert pm.assign(1).name == "a"
    assert pm.assign(1, exclude=("a",)) is None


def test_dead_endpoint_revivable_by_reregistration():
    pm = PlacementMap()
    pm.register("a", "127.0.0.1", 1)
    pm.report_dead("a")
    pm.register("a", "127.0.0.1", 1)  # daemon came back
    assert pm.endpoints["a"].alive


# -- the coordinator handshake ---------------------------------------------------

@pytest.fixture
def live_coordinator(tmp_path):
    """A Coordinator whose event loop is running (n_hosts=1); the fixture
    tears it down by joining as that host and reporting FINISHED."""
    coord = Coordinator(str(tmp_path / "root"), n_hosts=1).start()
    err = []

    def drive():
        try:
            coord.run(deadline_s=60.0)
        except Exception as e:
            err.append(e)

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    yield coord
    conn = connect(coord.address)
    conn.settimeout(1.0)
    conn.send(MSG_JOIN, host=0, pid=1, restored_from=None)
    while True:
        msg = conn.recv()
        if msg and msg.get("type") == MSG_WELCOME:
            break
    conn.send(MSG_FINISHED, host=0, step=0, digest="x")
    t.join(timeout=30)
    conn.close()
    assert not err, err


def test_register_acquire_dead_handshake(live_coordinator):
    coord = live_coordinator
    register_proxy_endpoint(coord.address, name="ph0", addr="127.0.0.1",
                            port=7001)
    register_proxy_endpoint(coord.address, name="ph1", addr="127.0.0.1",
                            port=7002)
    got = request_proxy_endpoint(coord.address, worker=0)
    assert got is not None and got["name"] in ("ph0", "ph1")
    # sticky across re-acquire
    again = request_proxy_endpoint(coord.address, worker=0)
    assert again["name"] == got["name"]
    # death report reschedules onto the survivor
    moved = request_proxy_endpoint(
        coord.address, worker=0, failed=got["name"], exclude=(got["name"],)
    )
    assert moved is not None and moved["name"] != got["name"]
    # all dead -> None (the worker surfaces budget exhaustion, not a hang)
    none = request_proxy_endpoint(
        coord.address, worker=0, failed=moved["name"],
        exclude=(got["name"], moved["name"]),
    )
    assert none is None
    # the journal recorded placements and the proxy-host death
    events = [e["event"] for e in _read_log(coord.log_path)]
    assert "proxy_endpoint" in events
    assert "proxy_placement" in events
    assert "proxy_host_death" in events


def test_malformed_side_channel_frame_never_kills_the_cluster(
    live_coordinator,
):
    """The side channel accepts arbitrary un-JOINed peers: a bad frame
    gets an error reply; the event loop (and the cluster) survives."""
    import socket as socket_mod
    from repro.coord.protocol import MSG_PROXY_ENDPOINT

    coord = live_coordinator
    conn = connect(coord.address)
    conn.settimeout(1.0)
    try:
        conn.send(MSG_PROXY_ENDPOINT, op="register", name="ghost")  # no port
        while True:
            try:
                msg = conn.recv()
                break
            except (socket_mod.timeout, TimeoutError):
                continue
        assert msg["type"] == MSG_PROXY_ENDPOINT
        assert "bad frame" in msg.get("error", "")
    finally:
        conn.close()
    # the coordinator still serves well-formed requests afterwards
    register_proxy_endpoint(coord.address, name="ok", addr="127.0.0.1",
                            port=7009)
    got = request_proxy_endpoint(coord.address, worker=5)
    assert got is not None and got["name"] == "ok"


def _read_log(path):
    import json

    with open(path) as f:
        return [json.loads(line) for line in f]
