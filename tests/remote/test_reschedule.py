"""Cross-endpoint drills (``remote`` marker; dedicated CI job): SIGKILL a
proxy-host daemon mid-run -> reschedule onto a survivor + API-log replay;
the coordinator-placed cluster variant; elastic N->M cluster restarts."""
import json
import shutil
import tempfile

import numpy as np
import pytest

from repro.coord.supervisor import run_cluster
from repro.proxy import ProxyRunner, make_program
from repro.utils.tree import tree_digest, tree_equal

pytestmark = pytest.mark.remote

SPEC = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}


def _inline_run(n_steps, spec=SPEC):
    prog = make_program(spec)
    s = prog.init_state()
    for step in range(1, n_steps + 1):
        s, _ = prog.step(s, step)
    return s


def test_daemon_kill_reschedules_onto_survivor():
    from repro.remote.host import ProxyHostHandle

    daemons = [ProxyHostHandle(f"r-ph{i}").start() for i in range(2)]
    order = list(daemons)
    used = []

    def provider(failed=False):
        if failed:
            order.pop(0)
        used.append(order[0].name)
        return order[0].addr

    ref = _inline_run(12)
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, transport="stream",
                    endpoint_provider=provider, max_restarts=2)
    r.start()
    try:
        for s in range(1, 7):
            r.step(s)
        r.sync_state()
        daemons[0].kill()  # the HOST dies, not just the session
        for s in range(7, 13):
            r.step(s)
        state, info = r.sync_state()
        assert r.restarts == 1
        assert used[0] != used[-1], "never moved endpoints"
        assert info["step"] == 12
        assert tree_equal(state, ref)
        assert info["digest"] == tree_digest(ref)
    finally:
        r.close()
        for d in daemons:
            d.terminate()


def test_cluster_proxy_host_kill_drill(tmp_path):
    """The acceptance drill: a worker's proxy lives on a remote endpoint;
    SIGKILL of that proxy host is survived — the coordinator reschedules
    onto a survivor, the API log replays, and training state is
    bit-identical to an unkilled run."""
    report = run_cluster(
        root=str(tmp_path / "cluster"), n_hosts=2, total_steps=6,
        ckpt_every=2, backend="thread", loop="numpy",
        device_runner="proxy", proxy_hosts=2, kill_proxy_host=0,
        deadline_s=300.0,
    )
    assert report.lockstep()
    assert report.latest_committed == 6
    assert report.killed_proxy_hosts == ["ph0"]
    # the audit trail shows at least one worker moving endpoints
    by_worker = {}
    for w, name in report.proxy_placements:
        by_worker.setdefault(w, []).append(name)
    moved = [w for w, names in by_worker.items() if len(set(names)) > 1]
    assert moved, f"no reschedule in {report.proxy_placements}"
    # the watchdog journaled the proxy-host death BEFORE any round that
    # committed on the rescheduled endpoint
    assert "proxy_host_death" in report.alert_kinds()
    with open(report.log_path) as f:
        log = [json.loads(line) for line in f]
    alert_i = next(i for i, e in enumerate(log) if e["event"] == "alert"
                   and e["kind"] == "proxy_host_death")
    commits_after = [e for e in log[alert_i:] if e["event"] == "round"
                     and e["status"] == "committed"]
    assert commits_after, "no committed round after the proxy-death alert"

    # bit-identical to an unkilled (local-proxy) run of the same config
    ref = run_cluster(
        root=str(tmp_path / "ref"), n_hosts=2, total_steps=6, ckpt_every=2,
        backend="thread", loop="numpy", device_runner="proxy",
        deadline_s=300.0,
    )
    assert ref.lockstep()
    assert set(ref.final_digests.values()) == set(
        report.final_digests.values()
    )


@pytest.mark.parametrize("n_new", [3, 6])
def test_cluster_elastic_restart_4_hosts_onto(tmp_path, n_new):
    """A committed 4-host checkpoint restores onto 3 and 6 hosts and the
    continued run lands on the bit-identical final state."""
    rows = max(4, n_new, 2) * 8  # state shape pinned across host counts
    spec = dict(SPEC, rows=rows, width=64)
    root = str(tmp_path / "cluster")
    phase1 = run_cluster(
        root=root, n_hosts=4, total_steps=2, ckpt_every=2,
        backend="thread", loop="numpy", rows=rows, width=64,
        deadline_s=300.0,
    )
    assert phase1.latest_committed == 2

    phase2 = run_cluster(
        root=root, n_hosts=n_new, total_steps=5, ckpt_every=2,
        backend="thread", loop="numpy", rows=rows, width=64,
        deadline_s=300.0,
    )
    assert phase2.lockstep()
    assert phase2.latest_committed == 4
    # every phase-2 worker restored from the 4-host image (the journal is
    # shared across phases: phase-1 joins carry restored_from=None)
    import json

    with open(phase2.log_path) as f:
        events = [json.loads(line) for line in f]
    restored = {e["host"] for e in events
                if e["event"] == "join" and e.get("restored_from") == 2}
    assert restored == set(range(n_new))
    # bit-identical to the same program run uninterrupted
    ref = _inline_run(5, spec)
    assert set(phase2.final_digests.values()) == {tree_digest(ref)}


def test_cluster_remote_proxies_happy_path(tmp_path):
    """No drill: coordinator-placed remote proxies just work, and the
    placement spreads workers across daemons."""
    report = run_cluster(
        root=str(tmp_path / "cluster"), n_hosts=2, total_steps=4,
        ckpt_every=2, backend="thread", loop="numpy",
        device_runner="proxy", proxy_hosts=2, deadline_s=300.0,
    )
    assert report.lockstep()
    assert report.latest_committed == 4
    assert report.aborted == []
    names = {name for _, name in report.proxy_placements}
    assert names == {"ph0", "ph1"}  # least-loaded spread, one each
