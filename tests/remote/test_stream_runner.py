"""The streamed transport under the supervised runner: parity with the
segment path, kill-replay, and a daemon-hosted (endpoint) proxy session."""
import numpy as np
import pytest

from repro.proxy import ProxyRunner, make_program
from repro.remote.host import ProxyHostHandle
from repro.utils.tree import tree_digest, tree_equal

pytestmark = pytest.mark.integration

SPEC = {"name": "numpy_sgd", "rows": 8, "width": 32, "seed": 0}


def _inline_run(n_steps, spec=SPEC):
    prog = make_program(spec)
    s = prog.init_state()
    for step in range(1, n_steps + 1):
        s, _ = prog.step(s, step)
    return s


def test_stream_kill_replay_bit_identical():
    ref = _inline_run(14)
    r = ProxyRunner(SPEC, chunk_bytes=1 << 10, transport="stream",
                    max_restarts=2)
    r.start()
    try:
        for s in range(1, 8):
            r.step(s)
        _, info = r.sync_state()
        assert info["step"] == 7
        r.kill()
        for s in range(8, 15):
            r.step(s)
        state, info = r.sync_state()
        assert r.restarts == 1
        assert info["step"] == 14
        assert tree_equal(state, ref)
        assert info["digest"] == tree_digest(ref)
    finally:
        r.close()


def test_stream_and_segment_transports_agree():
    digests = {}
    wire = {}
    for kind in ("segment", "stream"):
        r = ProxyRunner(SPEC, chunk_bytes=1 << 10, transport=kind)
        r.start()
        try:
            for s in range(1, 6):
                r.step(s)
            _, info = r.sync_state()
            digests[kind] = info["digest"]
            wire[kind] = info["transport"]
        finally:
            r.close()
    assert digests["segment"] == digests["stream"]
    # the streamed transport moved real payload on the connection; the
    # segment transport moved none
    assert wire["stream"]["wire_rx"] > 0
    assert wire["segment"]["wire_rx"] == 0


def test_endpoint_daemon_session_and_steady_state_delta():
    """A daemon-hosted proxy session: full state rides the wire once at
    start, then steady-state SYNC wire bytes track dirty chunks only."""
    d = ProxyHostHandle("t-ph0").start()
    r = ProxyRunner(
        SPEC, chunk_bytes=1 << 8, transport="stream",
        endpoint_provider=lambda failed=False: d.addr,
    )
    try:
        r.start()
        state_bytes = r.transport.table.total_bytes()
        assert r.transport.wire_tx >= state_bytes  # the initial full push
        for s in range(1, 4):
            r.step(s)
        _, info1 = r.sync_state()
        rx1 = r.transport.wire_rx
        # numpy_sgd dirties everything each step, so the first sync moves
        # ~the whole state; a sync with NO steps in between moves nothing
        _, info2 = r.sync_state()
        assert r.transport.wire_rx == rx1
        assert info2["chunks_synced"] == 0
        assert tree_equal(r.transport.read_state(), _inline_run(3))
    finally:
        r.close()
        d.terminate()


def test_endpoint_unreachable_surfaces_quickly():
    from repro.proxy.protocol import ProxyDiedError

    r = ProxyRunner(
        SPEC, chunk_bytes=1 << 10, max_restarts=0,
        transport="stream",
        endpoint_provider=lambda failed=False: ("127.0.0.1", 1),  # closed
    )
    with pytest.raises((ProxyDiedError, RuntimeError)):
        r.start()
