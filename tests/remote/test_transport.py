"""ChunkTransport unit + parity tests (the cheap, tier-1 half of the
remote subsystem; the daemon kill/reschedule drills are ``remote``-marked
in test_reschedule.py)."""
import numpy as np
import pytest

from repro.proxy.segments import PrivateTable, SegmentTable
from repro.remote.transport import (
    FRAME_PAYLOAD_BYTES,
    apply_chunk_frame,
    encode_chunk_frames,
    endpoint_arg,
    make_proxy_table,
    make_transport,
)


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 16)).astype(np.float32),
        "b": rng.standard_normal((16,)).astype(np.float32),
    }


CB = 1 << 8


def test_frame_roundtrip_private_tables():
    state = _state()
    src = PrivateTable.create(state)
    dst = PrivateTable.attach(src.layout)
    frames, raw, wire = encode_chunk_frames(src, src.all_chunks(CB), CB)
    assert raw == src.total_bytes()
    for f in frames:
        apply_chunk_frame(dst, {"type": "CHUNKS", **f}, CB)
    got = PrivateTable.attach(src.layout)
    got._buffers = dst._buffers
    got._treedef = src._treedef
    for path in src.layout:
        np.testing.assert_array_equal(dst.view(path), src.view(path))


def test_delta_frames_carry_only_named_chunks():
    state = _state()
    src = PrivateTable.create(state)
    dst = PrivateTable.attach(src.layout)
    # copy everything first, then mutate one chunk and send only it
    for f in encode_chunk_frames(src, src.all_chunks(CB), CB)[0]:
        apply_chunk_frame(dst, f, CB)
    w = np.asarray(state["w"]).copy()
    w.reshape(-1)[0] = 123.0
    src.write_state(dict(state, w=w))
    frames, raw, wire = encode_chunk_frames(src, {"w": [0]}, CB)
    assert raw == CB  # exactly one chunk's bytes
    for f in frames:
        apply_chunk_frame(dst, f, CB)
    np.testing.assert_array_equal(dst.view("w"), src.view("w"))
    np.testing.assert_array_equal(dst.view("b"), src.view("b"))


def test_frames_batch_under_payload_target():
    big = {"w": np.zeros(3 * FRAME_PAYLOAD_BYTES, np.uint8)}
    t = PrivateTable.create(big)
    cb = 1 << 16
    frames, raw, _ = encode_chunk_frames(t, t.all_chunks(cb), cb,
                                         compress=False)
    assert raw == 3 * FRAME_PAYLOAD_BYTES
    assert len(frames) >= 3
    for f in frames:
        assert len(f["data"]) <= FRAME_PAYLOAD_BYTES + cb
        assert sum(n for _, _, n in f["items"]) == len(f["data"])


def test_zstd_per_frame_when_available():
    zstd = pytest.importorskip("zstandard")
    # compressible content: zeros
    t = PrivateTable.create({"w": np.zeros(4 * CB, np.uint8)})
    frames, raw, wire = encode_chunk_frames(t, t.all_chunks(CB), CB,
                                            compress=True)
    assert wire < raw
    assert all(f["codec"] == "zstd" for f in frames)
    dst = PrivateTable.attach(t.layout)
    for f in frames:
        apply_chunk_frame(dst, f, CB)
    np.testing.assert_array_equal(dst.view("w"), t.view("w"))


def test_incompressible_frames_fall_back_to_raw():
    rng = np.random.default_rng(3)
    t = PrivateTable.create({"w": rng.integers(0, 256, 4 * CB).astype(np.uint8)})
    frames, raw, wire = encode_chunk_frames(t, t.all_chunks(CB), CB)
    # whether or not zstd exists, raw payload must never be inflated
    assert wire <= raw


def test_apply_frame_length_mismatch_rejected():
    t = PrivateTable.create({"w": np.zeros(2 * CB, np.uint8)})
    with pytest.raises(ValueError, match="items claim"):
        apply_chunk_frame(
            t, {"codec": "raw", "items": [["w", 0, CB]], "data": b"x" * (CB + 1)},
            CB,
        )


def test_write_range_bounds_checked():
    t = PrivateTable.create({"w": np.zeros(CB, np.uint8)})
    with pytest.raises(ValueError, match="outside leaf"):
        t.write_range("w", CB - 1, b"xx")
    with pytest.raises(KeyError):
        t.write_range("nope", 0, b"x")


def test_stream_transport_sync_ingest():
    state = _state()
    app = make_transport("stream", state, CB)
    # proxy side mutates, encodes changed chunks, app ingests via on_chunks
    proxy_table = make_proxy_table({"transport": "stream",
                                    "layout": app.table.layout})
    for f in encode_chunk_frames(app.table, app.table.all_chunks(CB), CB)[0]:
        apply_chunk_frame(proxy_table, f, CB)
    w = np.asarray(state["w"]).copy()
    w.reshape(-1)[7] = 42.0
    proxy_table.write_state(dict(state, w=w))
    frames, _, _ = encode_chunk_frames(proxy_table, {"w": [0]}, CB)
    for f in frames:
        app.on_chunks({"type": "CHUNKS", **f})
    got = app.read_state()
    np.testing.assert_array_equal(got["w"], w)
    assert app.wire_rx > 0


def test_segment_transport_rejects_chunks_frames():
    app = make_transport("segment", _state(), CB)
    try:
        with pytest.raises(RuntimeError, match="does not expect"):
            app.on_chunks({"codec": "raw", "items": [], "data": b""})
    finally:
        app.close(unlink=True)


def test_make_proxy_table_kinds(tmp_path):
    state = _state()
    seg = SegmentTable.create(state, workdir=str(tmp_path))
    t = make_proxy_table({"workdir": str(tmp_path), "layout": seg.layout})
    assert isinstance(t, SegmentTable)
    np.testing.assert_array_equal(t.view("w"), seg.view("w"))
    t2 = make_proxy_table({"transport": "stream", "layout": seg.layout})
    assert isinstance(t2, PrivateTable)
    with pytest.raises(ValueError, match="unknown transport"):
        make_proxy_table({"transport": "carrier-pigeon", "layout": {}})
    seg.close(unlink=True)


def test_endpoint_arg():
    assert endpoint_arg("10.0.0.2:7070") == ("10.0.0.2", 7070)
    with pytest.raises(ValueError):
        endpoint_arg("7070")
    with pytest.raises(ValueError):
        endpoint_arg("host:")
