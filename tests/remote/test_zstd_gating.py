"""zstd availability gating + trained-dictionary codec.

The explicit contract (previously only implicit): ``compress=True``
without the optional ``zstandard`` package is a clear, immediate error;
``compress=None`` silently degrades to raw frames; a ``zstd-dict`` frame
arriving where no dictionary was registered fails loudly instead of
corrupting the table. The dictionary round-trip tests run only where
zstandard exists.
"""
import numpy as np
import pytest

import repro.remote.transport as transport_mod
from repro.proxy.segments import PrivateTable
from repro.remote.transport import (
    apply_chunk_frame,
    encode_chunk_frames,
    make_transport,
    train_chunk_dict,
)

CB = 1 << 8


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 16)).astype(np.float32),
        "b": rng.standard_normal((16,)).astype(np.float32),
    }


@pytest.fixture
def no_zstd(monkeypatch):
    monkeypatch.setattr(transport_mod, "_zstd", lambda: None)


def test_compress_true_without_zstd_is_a_clear_error(no_zstd):
    t = PrivateTable.create(_state())
    with pytest.raises(RuntimeError, match="zstandard is not installed"):
        encode_chunk_frames(t, t.all_chunks(CB), CB, compress=True)


def test_compress_auto_without_zstd_passes_raw(no_zstd):
    src = PrivateTable.create(_state())
    dst = PrivateTable.attach(src.layout)
    frames, raw, wire = encode_chunk_frames(
        src, src.all_chunks(CB), CB, compress=None
    )
    assert wire == raw  # nothing compressed, nothing inflated
    assert all(f["codec"] == "raw" for f in frames)
    for f in frames:
        apply_chunk_frame(dst, f, CB)
    np.testing.assert_array_equal(dst.view("w"), src.view("w"))


def test_zstd_frame_without_zstd_receiver_is_a_clear_error(no_zstd):
    t = PrivateTable.create(_state())
    with pytest.raises(RuntimeError, match="zstandard is not installed"):
        apply_chunk_frame(
            t, {"codec": "zstd", "items": [["w", 0, CB]], "data": b"x"}, CB
        )


def test_train_chunk_dict_without_zstd_returns_none(no_zstd):
    t = PrivateTable.create(_state())
    assert train_chunk_dict(t, CB) is None


def test_make_transport_train_dict_degrades_without_zstd(no_zstd):
    tr = make_transport("stream", _state(), CB, train_dict=True)
    assert tr.zdict is None
    assert "zdict" not in tr.register_fields()
    tr.close(unlink=True)


def test_stream_transport_counts_frames_and_chunks():
    tr = make_transport("stream", _state(), CB, compress=False)
    frames = tr.payload_frames(None)
    assert tr.frames_tx == len(frames)
    assert tr.chunks_tx == sum(len(f["items"]) for f in frames)
    # coalescing: far fewer frames than chunks for small-chunk states
    assert tr.frames_tx < tr.chunks_tx
    for f in frames:
        tr.on_chunks({"type": "CHUNKS", **f})
    assert tr.frames_rx == len(frames)
    assert tr.chunks_rx == tr.chunks_tx
    stats = tr.stats()
    assert stats["frames_tx"] == tr.frames_tx
    assert stats["chunks_rx"] == tr.chunks_rx
    tr.close(unlink=True)


# -- trained-dictionary codec (needs the real zstandard) ---------------------

def test_dict_codec_roundtrip():
    zstd = pytest.importorskip("zstandard")
    # repetitive content: a dictionary has something to learn
    state = {"w": np.tile(np.arange(64, dtype=np.uint8), 256)}
    src = PrivateTable.create(state)
    zdict = train_chunk_dict(src, CB)
    if zdict is None:
        pytest.skip("samples too small to train a dictionary")
    frames, raw, wire = encode_chunk_frames(
        src, src.all_chunks(CB), CB, compress=True, dict_bytes=zdict
    )
    assert any(f["codec"] == "zstd-dict" for f in frames)
    assert wire < raw
    dst = PrivateTable.attach(src.layout)
    for f in frames:
        apply_chunk_frame(dst, f, CB, dict_bytes=zdict)
    np.testing.assert_array_equal(dst.view("w"), src.view("w"))


def test_dict_frame_without_registered_dict_is_a_clear_error():
    zstd = pytest.importorskip("zstandard")
    state = {"w": np.tile(np.arange(64, dtype=np.uint8), 256)}
    src = PrivateTable.create(state)
    zdict = train_chunk_dict(src, CB)
    if zdict is None:
        pytest.skip("samples too small to train a dictionary")
    frames, _, _ = encode_chunk_frames(
        src, src.all_chunks(CB), CB, compress=True, dict_bytes=zdict
    )
    frame = next(f for f in frames if f["codec"] == "zstd-dict")
    dst = PrivateTable.attach(src.layout)
    with pytest.raises(RuntimeError, match="no trained dictionary"):
        apply_chunk_frame(dst, frame, CB)
