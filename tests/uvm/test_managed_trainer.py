"""Oversubscribed training through CheckpointedTrainer: the acceptance
drill — device_capacity = 50% of the model state; train, checkpoint,
restore bit-identically, over both persist backends."""
import os

import numpy as np
import pytest

from repro.core import CheckpointedTrainer, CheckpointPolicy
from repro.utils.tree import tree_equal

BACKENDS = ["thread"] + (["fork"] if hasattr(os, "fork") else [])

N = 32 * 1024  # 128 KiB main leaf


def _step_fn(dev, batch):
    w = np.asarray(dev["w"] * 1.0001 + batch, np.float32)
    return {"w": w, "b": dev["b"] + 1}, {"loss": float(w.sum())}


def _batches(start=0):
    i = start
    while True:
        i += 1
        yield np.float32(i * 1e-3)


def _init_state():
    return {
        "device": {"w": np.arange(N, dtype=np.float32) / 1e3,
                   "b": np.zeros(8, np.float32)},
        "host": {"step": np.int64(0)},
    }


def _state_bytes() -> int:
    s = _init_state()["device"]
    return sum(v.nbytes for v in s.values())


def _trainer(root, backend, capacity, **kw):
    return CheckpointedTrainer(
        _step_fn,
        store_root=str(root),
        policy=CheckpointPolicy(interval_steps=2, keep_last=2),
        chunk_bytes=8192,
        backend=backend,
        device_capacity_bytes=capacity,
        page_bytes=4096,
        **kw,
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_oversubscribed_roundtrip_bit_identical(tmp_path, backend):
    cap = _state_bytes() // 2  # the acceptance ratio: 50% of state
    tr = _trainer(tmp_path / backend, backend, cap)
    state, start = tr.resume_or(_init_state)
    state = tr.run(state, _batches(), num_steps=5, start_step=start)
    tr.finish()
    assert tr.space is not None
    tr.space.check_invariants()
    assert tr.space.stats.evictions > 0, "50% capacity must actually page"
    assert tr.space.device_bytes_resident() <= cap

    # reference: identical run, no managed memory
    ref_tr = CheckpointedTrainer(
        _step_fn, store_root=str(tmp_path / "ref"),
        policy=CheckpointPolicy(interval_steps=100),
    )
    ref, _ = ref_tr.resume_or(_init_state)
    ref = ref_tr.run(ref, _batches(), num_steps=5, start_step=0)
    ref_tr.finish()
    assert tree_equal(state["device"], ref["device"]), (
        "paging must be transparent: managed == unmanaged bit-for-bit"
    )

    # restore (also oversubscribed) lands exactly on the step-4 checkpoint
    tr2 = _trainer(tmp_path / backend, backend, cap)
    restored, start2 = tr2.resume_or(_init_state)
    assert start2 == 4
    # continue to step 5 and re-converge with the uninterrupted run
    restored = tr2.run(restored, _batches(4), num_steps=1, start_step=start2)
    tr2.finish()
    assert tree_equal(restored["device"], state["device"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_managed_checkpoints_use_page_delta_sync(tmp_path, backend):
    """After the first image, phase-1 sync cost tracks pages dirtied (all
    pages here — but the host leaves prove marks flow: only the managed
    paths get precise treatment and nothing is missed)."""
    cap = _state_bytes()  # x1.0: no paging, pure delta accounting
    tr = _trainer(tmp_path / "d", backend, cap)
    state, start = tr.resume_or(_init_state)
    state = tr.run(state, _batches(), num_steps=4, start_step=start)
    done = tr.finish()
    assert len(done) == 2
    first, second = sorted(done, key=lambda r: r.step)
    assert first.chunks_clean == 0          # everything moves into image 1
    assert second.chunks_synced > 0         # the steps dirtied real chunks
    assert second.error is None and first.error is None
    # restore proves the delta image is complete
    tr2 = _trainer(tmp_path / "d", backend, cap)
    restored, start2 = tr2.resume_or(_init_state)
    assert start2 == 4
    assert tree_equal(restored["device"], state["device"])
    tr2.finish()


def test_managed_trainer_materialize_and_stats(tmp_path):
    tr = _trainer(tmp_path / "m", "thread", _state_bytes() // 2)
    state, start = tr.resume_or(_init_state)
    state = tr.run(state, _batches(), num_steps=2, start_step=start)
    # materialize is idempotent and matches the space's coherent view
    m1 = tr.materialize(dict(state))
    assert tree_equal(m1["device"], state["device"])
    stats = tr.paging_stats()
    assert stats is not None and stats["faults"] > 0
    assert stats["device_capacity_bytes"] == _state_bytes() // 2
    tr.finish()


def test_preemption_checkpoints_step_exactly_once(tmp_path):
    """SIGTERM sets BOTH the policy preempt flag and the stop event: the
    loop checkpoints the step via the policy, and the caller-side guard
    must not save the same step a second time (two concurrent persists of
    one step directory would tear its files)."""
    from repro.core import PreemptionHandler
    from repro.launch.train import _needs_preempt_ckpt

    tr = _trainer(tmp_path / "p", "thread", _state_bytes() // 2)
    tr.policy.interval_steps = 50  # no cadence checkpoint in this window
    preempt = PreemptionHandler(tr.policy).install()
    try:
        state, start = tr.resume_or(_init_state)

        def on_metrics(step, m):
            if step == 3:
                preempt.received.set()
                tr.policy.request_preempt_checkpoint()

        state = tr.run(state, _batches(), num_steps=100, start_step=start,
                       on_metrics=on_metrics, stop=preempt.received.is_set)
        step = int(np.asarray(state["host"]["step"]))
        assert step == 3
        assert [r.step for r in tr.results] == [3]
        assert not _needs_preempt_ckpt(tr, step)
        tr.finish()
    finally:
        preempt.uninstall()


def test_run_stop_hook_exits_early(tmp_path):
    """The preemption seam: run(stop=...) ends the loop after the current
    step instead of grinding out the remaining budget."""
    tr = _trainer(tmp_path / "s", "thread", _state_bytes() // 2)
    state, start = tr.resume_or(_init_state)
    seen = []
    state = tr.run(
        state, _batches(), num_steps=1000, start_step=start,
        on_metrics=lambda s, m: seen.append(s),
        stop=lambda: len(seen) >= 3,
    )
    tr.finish()
    assert seen == [1, 2, 3]
    assert int(np.asarray(state["host"]["step"])) == 3


@pytest.mark.paging_stress
@pytest.mark.parametrize("policy", ["lru", "clock"])
def test_paging_stress_large_oversubscription(tmp_path, policy):
    """Heavy drill (excluded from tier-1): a 4 MiB state at 4x
    oversubscription, many checkpoint rounds, restore at the end."""
    big_n = 1 << 20  # 4 MiB f32

    def init():
        return {
            "device": {"w": np.arange(big_n, dtype=np.float32),
                       "b": np.zeros(64, np.float32)},
            "host": {"step": np.int64(0)},
        }

    cap = (big_n * 4 + 256) // 4  # x4 oversubscription
    tr = CheckpointedTrainer(
        _step_fn, store_root=str(tmp_path / policy),
        policy=CheckpointPolicy(interval_steps=2, keep_last=2),
        chunk_bytes=1 << 18, backend="thread",
        device_capacity_bytes=cap, page_bytes=1 << 16,
        eviction_policy=policy,
    )
    state, start = tr.resume_or(init)
    state = tr.run(state, _batches(), num_steps=8, start_step=start)
    tr.finish()
    tr.space.check_invariants()
    assert tr.space.stats.evictions > 100
    tr2 = CheckpointedTrainer(
        _step_fn, store_root=str(tmp_path / policy),
        device_capacity_bytes=cap, page_bytes=1 << 16,
        eviction_policy=policy,
    )
    restored, start2 = tr2.resume_or(init)
    assert start2 == 8
    assert tree_equal(restored["device"], state["device"])
    tr2.finish()
