"""Device proxy under oversubscription + chunk-delta UPLOAD frames.

Integration-marked: spawns real proxy processes. The wire-level assertion
is the satellite's contract: bytes on the (segment) wire scale with dirty
chunks, not state size.
"""
import numpy as np
import pytest

from repro.proxy import ProxyRunner
from repro.utils.tree import tree_digest

pytestmark = pytest.mark.integration

SPEC = {"name": "numpy_sgd", "rows": 64, "width": 128, "seed": 0}
CHUNK = 4096


def _runner(**kw):
    return ProxyRunner(SPEC, chunk_bytes=CHUNK, **kw)


def _state_bytes(state) -> int:
    return sum(np.asarray(v).nbytes for v in state.values())


def test_paged_proxy_kill_replay_bit_identical():
    """The oversubscription kill drill: a proxy hosting a state at 2x its
    device budget is SIGKILLed mid-run; replay must land bit-identically
    on the uninterrupted run's digest."""
    ref = _runner()
    st0 = ref.start()
    for s in range(1, 7):
        ref.step(s)
    ref_state, ref_info = ref.sync_state()
    ref.close()

    cap = max(8192, _state_bytes(st0) // 2)
    r = _runner(device_capacity_bytes=cap, page_bytes=4096)
    r.start()
    for s in range(1, 4):
        r.step(s)
    r.sync_state()
    r.kill()
    for s in range(4, 7):
        r.step(s)  # transport death detected here -> respawn + replay
    state, info = r.sync_state()
    r.close()
    assert r.restarts == 1
    assert info["digest"] == ref_info["digest"]
    assert tree_digest(state) == tree_digest(ref_state)
    # the SYNCED frame carries the proxy-side paging counters
    assert info["paging"]["faults"] > 0
    assert info["paging"]["device_capacity_bytes"] == cap


def test_delta_upload_bytes_on_wire_scale_with_dirty_chunks():
    """Wire-level: push states differing by k chunks; the data-plane bytes
    and the proxy's UPLOAD ack must scale with k, not with state size."""
    r = _runner()
    r.start()
    for s in range(1, 3):
        r.step(s)
    state, _ = r.sync_state()
    total = _state_bytes(state)
    key = max(state, key=lambda k: np.asarray(state[k]).nbytes)

    wire = []
    for k_chunks in (1, 3):
        new = {k: np.array(v) for k, v in state.items()}
        flat = new[key].reshape(-1).view(np.uint8)
        for c in range(k_chunks):
            flat[c * CHUNK] ^= 0xFF  # one byte per target chunk
        seg_before = r.segments.bytes_written
        ack = r.push(new)
        seg_bytes = r.segments.bytes_written - seg_before
        wire.append((k_chunks, seg_bytes, ack))
        assert ack["chunks_uploaded"] == k_chunks
        assert ack["bytes_uploaded"] <= k_chunks * CHUNK
        assert seg_bytes <= k_chunks * CHUNK
        assert seg_bytes < total // 4, "delta must not rewrite the state"
        state = new

    (k1, b1, _), (k3, b3, _) = wire
    assert b3 == 3 * b1, "bytes-on-wire must scale linearly with dirty chunks"
    # and the proxy's device state took the delta correctly
    st2, info = r.sync_state()
    assert info["digest"] == tree_digest(state)
    r.close()


def test_delta_upload_into_paged_proxy():
    """The delta path composes with proxy-side paging: a partial push into
    an oversubscribed proxy lands in the managed space coherently AND does
    not dirty the untouched pages (the next page-delta SYNC stays small)."""
    boot = _runner()
    st0 = boot.start()
    boot.close()
    cap = max(8192, _state_bytes(st0) // 2)

    r = _runner(device_capacity_bytes=cap, page_bytes=4096)
    r.start()
    r.step(1)
    state, _ = r.sync_state()
    new = {k: np.array(v) for k, v in state.items()}
    key = max(new, key=lambda k: np.asarray(new[k]).nbytes)
    new[key].reshape(-1)[:8] += 1.5
    ack = r.push(new)
    assert ack["chunks_uploaded"] == 1
    _, info = r.sync_state()
    assert info["digest"] == tree_digest(new)
    # a 1-chunk delta must not make the whole state look dirty: this sync
    # re-fetched at most the spliced chunk's pages (chunk == page here)
    assert info["chunks_synced"] <= 1, (
        f"delta upload dirtied {info['chunks_synced']} chunks"
    )
    r.close()


def test_push_after_unsynced_steps_falls_back_to_full_upload():
    """A delta diffed against a stale mirror would under-upload: with STEP
    frames outstanding past the last sync, push() must rewrite fully so
    the device provably lands on the pushed state."""
    r = _runner()
    r.start()
    r.step(1)
    state, _ = r.sync_state()  # mirror = S1
    r.step(2)
    r.step(3)                  # device is past the mirror now
    total = _state_bytes(state)
    seg_before = r.segments.bytes_written
    ack = r.push({k: np.array(v) for k, v in state.items()})  # roll back to S1
    assert r.segments.bytes_written - seg_before == total, "must be a full rewrite"
    assert ack["bytes_uploaded"] == total
    _, info = r.sync_state()
    assert info["digest"] == tree_digest(state), "device must be AT the pushed state"
    r.close()


def test_full_push_when_no_mirror_compatible():
    """A shape-incompatible push falls back to a full segment rewrite."""
    r = _runner()
    r.start()
    state, _ = r.sync_state()
    # same tree, same shapes — but scrub the mirror to simulate "no mirror"
    r._last_state = None
    seg_before = r.segments.bytes_written
    r.push({k: np.array(v) for k, v in state.items()})
    assert r.segments.bytes_written - seg_before == _state_bytes(state)
    r.close()
