"""Page-table + pager state machine: fault/evict/write-back transitions."""
import numpy as np
import pytest

from repro.uvm import (
    Advice,
    DeviceArena,
    ManagedSpace,
    PageTable,
    PageTableError,
    Residency,
)

PAGE = 1024


def _space(total_pages=8, capacity_pages=4, policy="lru", **kw):
    sp = ManagedSpace(capacity_pages * PAGE, page_bytes=PAGE,
                      eviction_policy=policy, **kw)
    sp.register({"x": np.arange(total_pages * PAGE // 4, dtype=np.float32)})
    return sp


def test_pages_start_host_resident():
    sp = _space()
    t = sp.table("x")
    assert np.all(t.residency == Residency.HOST)
    assert np.all(t.frame == -1)
    sp.check_invariants()


def test_read_fault_migrates_to_device():
    sp = _space()
    sp.read_range("x", 0, PAGE)
    t = sp.table("x")
    assert t.residency[0] == Residency.DEVICE
    assert t.frame[0] >= 0
    assert not t.wb_dirty[0]
    assert sp.stats.faults_read == 1
    assert sp.stats.h2d_bytes == PAGE
    sp.check_invariants()


def test_resident_access_is_a_hit_not_a_fault():
    sp = _space()
    sp.read_range("x", 0, PAGE)
    sp.read_range("x", 0, PAGE)
    assert sp.stats.faults == 1
    assert sp.stats.hits == 1


def test_write_fault_sets_dirty_and_tick():
    sp = _space()
    t0 = sp.tick()
    sp.write_range("x", 0, np.ones(PAGE // 4, np.float32))
    t = sp.table("x")
    assert t.wb_dirty[0]
    assert t.write_tick[0] > t0
    assert sp.stats.faults_write == 1
    # full-page overwrite is write-allocate: no stale h2d copy
    assert sp.stats.h2d_bytes == 0


def test_partial_page_write_pulls_page_first():
    sp = _space()
    sp.write_range("x", 16, np.ones(4, np.float32))
    # the rest of the page must survive the partial write
    got = sp.peek_leaf("x")
    ref = np.arange(8 * PAGE // 4, dtype=np.float32)
    ref[4:8] = 1.0
    assert np.array_equal(got, ref)
    assert sp.stats.h2d_bytes == PAGE  # the pull


def test_eviction_writes_back_dirty_page():
    """The core invariant: dirty pages are never dropped without write-back."""
    sp = _space(total_pages=8, capacity_pages=2)
    sp.write_range("x", 0, np.full(PAGE // 4, 7.0, np.float32))  # page 0 dirty
    # touch enough other pages to force page 0 out of the 2-frame arena
    for p in range(1, 8):
        sp.read_range("x", p * PAGE, (p + 1) * PAGE)
    t = sp.table("x")
    assert t.residency[0] == Residency.HOST, "no DEVICE-resident page after eviction"
    assert t.frame[0] == -1
    assert not t.wb_dirty[0]
    assert sp.stats.writebacks >= 1
    # and the written bytes survived in the host backing
    assert np.all(sp.peek_leaf("x")[: PAGE // 4] == 7.0)
    # eviction does NOT erase checkpoint dirty history
    assert 0 in sp.dirty_pages_since("x", -1)
    sp.check_invariants()


def test_budget_is_hard():
    sp = _space(total_pages=16, capacity_pages=3)
    sp.read_range("x", 0, 16 * PAGE)
    assert sp.device_bytes_resident() <= sp.device_capacity_bytes
    sp.check_invariants()


def test_read_mostly_duplicates_and_write_collapses():
    sp = _space()
    sp.advise("x", Advice.READ_MOSTLY)
    sp.read_range("x", 0, PAGE)
    t = sp.table("x")
    assert t.residency[0] == Residency.BOTH
    sp.check_invariants()
    sp.write_range("x", 0, np.ones(PAGE // 4, np.float32))
    assert t.residency[0] == Residency.DEVICE  # duplication collapsed
    assert t.wb_dirty[0]
    sp.check_invariants()


def test_prefetch_counts_as_prefetch_not_fault():
    sp = _space()
    moved = sp.prefetch("x", 0, 3)
    assert moved == 3
    assert sp.stats.prefetches == 3
    assert sp.stats.faults == 0
    # subsequent reads are hits
    sp.read_range("x", 0, 3 * PAGE)
    assert sp.stats.faults == 0
    assert sp.stats.hits == 3


def test_preferred_host_evicted_first():
    state = {"a": np.zeros(2 * PAGE, np.uint8), "b": np.zeros(4 * PAGE, np.uint8)}
    sp = ManagedSpace(2 * PAGE, page_bytes=PAGE)
    sp.register(state)
    sp.advise("a", Advice.PREFERRED_HOST)
    sp.read_range("b", 0, PAGE)       # b0 resident (LRU-oldest)
    sp.read_range("a", 0, PAGE)       # a0 resident; arena full
    sp.read_range("b", PAGE, 2 * PAGE)  # needs a frame: victim must be a0,
    ta, tb = sp.table("a"), sp.table("b")  # not the LRU-oldest b0
    assert ta.residency[0] == Residency.HOST
    assert tb.residency[0] != Residency.HOST
    assert tb.residency[1] != Residency.HOST
    sp.check_invariants()


def test_preferred_device_evicted_last():
    state = {"a": np.zeros(2 * PAGE, np.uint8), "b": np.zeros(4 * PAGE, np.uint8)}
    sp = ManagedSpace(2 * PAGE, page_bytes=PAGE)
    sp.register(state)
    sp.advise("a", Advice.PREFERRED_DEVICE)
    sp.read_range("a", 0, PAGE)       # a0 resident (LRU-oldest)
    sp.read_range("b", 0, PAGE)       # b0 resident; arena full
    sp.read_range("b", PAGE, 2 * PAGE)  # victim must be b0, not advised a0
    ta, tb = sp.table("a"), sp.table("b")
    assert ta.residency[0] != Residency.HOST
    assert tb.residency[0] == Residency.HOST
    sp.check_invariants()


def test_invariant_checker_catches_corruption():
    sp = _space()
    sp.read_range("x", 0, PAGE)
    t = sp.table("x")
    t.wb_dirty[1] = True  # HOST page marked dirty = dropped write
    with pytest.raises(PageTableError):
        t.check_invariants()


def test_arena_smaller_than_one_page_rejected():
    with pytest.raises(ValueError):
        DeviceArena(PAGE - 1, PAGE)


def test_clock_policy_round_trip():
    sp = _space(total_pages=12, capacity_pages=3, policy="clock")
    out = sp.read_leaf("x")
    assert np.array_equal(out, np.arange(12 * PAGE // 4, dtype=np.float32))
    assert sp.stats.evictions >= 9
    sp.check_invariants()
