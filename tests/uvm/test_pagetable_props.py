"""Property tests: random op sequences vs a flat reference model.

The reference model is the simplest possible semantics — a plain byte
array per region that every write lands in immediately. Whatever the
pager does (fault, duplicate, evict, write back, prefetch, invalidate),
three things must hold after every op:

  - coherent reads (peek AND faulting read) equal the reference bytes
    ("dirty pages are never dropped without write-back"),
  - the device budget is never exceeded and the page-table invariants
    hold ("no DEVICE-resident page after eviction" etc.),
  - dirty history is complete: every chunk whose reference bytes changed
    since a captured tick appears in dirty_chunk_marks_since(tick).
"""
import numpy as np
import pytest

from repro.utils.testing import HAVE_HYPOTHESIS, given, settings, st

from repro.uvm import Advice, ManagedSpace

if not HAVE_HYPOTHESIS:
    pytest.skip("hypothesis not installed (pip install .[test])",
                allow_module_level=True)

PAGE = 512
N_PAGES = 10
CAP_PAGES = 3
CHUNK = 768  # deliberately NOT page-aligned: chunk/page mapping must cope


def _ops():
    span = st.tuples(
        st.integers(0, N_PAGES * PAGE - 1), st.integers(1, 3 * PAGE)
    )
    return st.lists(
        st.one_of(
            st.tuples(st.just("read"), span),
            st.tuples(st.just("write"), span, st.integers(0, 255)),
            st.tuples(st.just("peek"), span),
            st.tuples(st.just("prefetch"),
                      st.integers(0, N_PAGES - 1), st.integers(1, N_PAGES)),
            st.tuples(st.just("advise"), st.sampled_from(
                [Advice.NONE, Advice.READ_MOSTLY, Advice.PREFERRED_HOST,
                 Advice.PREFERRED_DEVICE])),
            st.tuples(st.just("load"), st.integers(0, 255)),
        ),
        min_size=1, max_size=40,
    )


@settings(max_examples=60, deadline=None)
@given(ops=_ops(), policy=st.sampled_from(["lru", "clock"]))
def test_space_matches_reference_model(ops, policy):
    sp = ManagedSpace(CAP_PAGES * PAGE, page_bytes=PAGE,
                      eviction_policy=policy, fault_window_pages=2)
    ref = np.zeros(N_PAGES * PAGE, np.uint8)
    sp.register({"r": ref.copy()})
    tick0 = sp.tick()
    ref0 = ref.copy()

    for op in ops:
        kind = op[0]
        if kind in ("read", "write", "peek"):
            lo, length = op[1]
            hi = min(N_PAGES * PAGE, lo + length)
            if lo >= hi:
                continue
        if kind == "read":
            got = sp.read_range("r", lo, hi)
            assert np.array_equal(got, ref[lo:hi])
        elif kind == "peek":
            got = sp.peek_range("r", lo, hi)
            assert np.array_equal(got, ref[lo:hi])
        elif kind == "write":
            val = np.full(hi - lo, op[2], np.uint8)
            sp.write_range("r", lo, val)
            ref[lo:hi] = val
        elif kind == "prefetch":
            lo_p = op[1]
            sp.prefetch("r", lo_p, min(N_PAGES, lo_p + op[2]))
        elif kind == "advise":
            sp.advise("r", op[1])
        elif kind == "load":
            ref[:] = op[1]
            sp.load_leaf("r", ref.copy())
        # the three standing invariants, after EVERY op
        sp.check_invariants()
        assert sp.device_bytes_resident() <= sp.device_capacity_bytes

    # final coherence through both read paths
    assert np.array_equal(sp.peek_range("r", 0, ref.nbytes), ref)
    assert np.array_equal(sp.read_range("r", 0, ref.nbytes), ref)
    sp.check_invariants()

    # dirty history completeness: every chunk that actually changed since
    # tick0 must be marked (marks may over-approximate, never miss)
    marked = set(sp.dirty_chunk_marks_since(tick0, CHUNK)["r"])
    n_chunks = -(-ref.nbytes // CHUNK)
    for c in range(n_chunks):
        lo, hi = c * CHUNK, min(ref.nbytes, (c + 1) * CHUNK)
        if not np.array_equal(ref[lo:hi], ref0[lo:hi]):
            assert c in marked, f"changed chunk {c} missing from dirty marks"


@settings(max_examples=40, deadline=None)
@given(
    writes=st.lists(
        st.tuples(st.integers(0, N_PAGES - 1), st.integers(0, 255)),
        min_size=1, max_size=12,
    )
)
def test_eviction_always_writes_back(writes):
    """Write pages, then force total eviction pressure: every written byte
    must survive in the host backing, and nothing stays DEVICE-resident
    after evict_table."""
    sp = ManagedSpace(2 * PAGE, page_bytes=PAGE)
    sp.register({"r": np.zeros(N_PAGES * PAGE, np.uint8)})
    ref = np.zeros(N_PAGES * PAGE, np.uint8)
    for page, val in writes:
        data = np.full(PAGE, val, np.uint8)
        sp.write_range("r", page * PAGE, data)
        ref[page * PAGE : (page + 1) * PAGE] = val
    table = sp.table("r")
    sp.pager.evict_table(table)
    assert table.device_pages().size == 0, "no DEVICE-resident page after eviction"
    assert not table.wb_dirty.any(), "dirty bit survived eviction"
    # host backing alone (no overlay possible now) equals the reference
    region_host = sp._regions["r"].host
    assert np.array_equal(region_host, ref)
    sp.check_invariants()
