"""Access-counter-driven promotion (Volta-style): a HOST page is promoted
to a device frame only after N reads within a window; colder reads are
served remotely (no migration, no frame pressure)."""
import numpy as np

from repro.uvm import ManagedSpace

PAGE = 256


def _space(threshold, window=0, n_pages=8, cap_pages=8):
    state = {
        "w": np.arange(n_pages * PAGE, dtype=np.uint8),
        "other": np.zeros(2 * PAGE, np.uint8),
    }
    sp = ManagedSpace(
        cap_pages * PAGE, page_bytes=PAGE,
        promote_threshold=threshold, promote_window=window,
    )
    sp.register(state)
    return sp, state


def test_cold_reads_stay_host_until_threshold():
    sp, state = _space(threshold=3)
    for i in range(1, 3):
        out = sp.read_leaf("w")
        np.testing.assert_array_equal(out, state["w"])  # remote reads serve
        assert sp.device_bytes_resident() == 0, f"read {i} migrated early"
        assert sp.stats.promotions == 0
    assert sp.stats.remote_reads == 2 * 8
    assert sp.stats.remote_read_bytes == 2 * 8 * PAGE
    # the third read crosses the threshold: every page promotes
    out = sp.read_leaf("w")
    np.testing.assert_array_equal(out, state["w"])
    assert sp.stats.promotions == 8
    assert sp.device_bytes_resident() == 8 * PAGE
    # promoted pages are ordinary resident pages now: further reads hit
    hits_before = sp.stats.hits
    sp.read_leaf("w")
    assert sp.stats.hits == hits_before + 8
    sp.check_invariants()


def test_threshold_zero_is_first_touch_migration():
    sp, state = _space(threshold=0)
    sp.read_leaf("w")
    assert sp.stats.remote_reads == 0
    assert sp.stats.faults_read == 8
    assert sp.device_bytes_resident() == 8 * PAGE


def test_writes_always_migrate_write_allocate():
    sp, _ = _space(threshold=5)
    sp.write_range("w", 0, np.ones(PAGE, np.uint8))
    assert sp.device_bytes_resident() == PAGE  # no remote-write path
    assert sp.stats.remote_reads == 0
    assert bool(sp.table("w").wb_dirty[0])
    sp.check_invariants()


def test_window_expiry_resets_the_count():
    # threshold 2, window 1 tick: two back-to-back reads promote...
    sp, _ = _space(threshold=2, window=1)
    sp.read_leaf("w")
    sp.read_leaf("w")
    assert sp.stats.promotions == 8

    # ...but a stale first read (window expired) does NOT count toward
    # the second: reads separated by > window ticks stay remote
    sp2, _ = _space(threshold=2, window=1)
    sp2.read_leaf("w")
    for _ in range(3):  # other-region reads advance the access clock
        sp2.read_leaf("other")
    sp2.read_leaf("w")  # 4 ticks later: counter restarted, still remote
    assert sp2.stats.promotions == 2  # only 'other' (2 pages, 2nd read)
    assert sp2.device_bytes_resident() == 2 * PAGE  # only 'other'
    assert sp2.table("w").residency.max() == 0  # w fully HOST


def test_promoted_content_correct_after_mixed_access():
    sp, state = _space(threshold=2)
    sp.read_leaf("w")                      # remote
    sp.write_range("w", 3 * PAGE, np.full(PAGE, 7, np.uint8))  # migrates p3
    out = sp.read_leaf("w")                # promotes the rest
    want = state["w"].copy()
    want[3 * PAGE : 4 * PAGE] = 7
    np.testing.assert_array_equal(out, want)
    np.testing.assert_array_equal(sp.peek_leaf("w"), want)
    sp.check_invariants()


def test_stats_dict_reports_promotion_fields():
    sp, _ = _space(threshold=3)
    sp.read_leaf("w")
    d = sp.stats_dict()
    assert d["promote_threshold"] == 3
    assert d["remote_reads"] == 8
    assert d["promotions"] == 0
