"""ManagedSpace: pytree-level coherence, dirty history, oversubscription."""
import numpy as np
import pytest

from repro.uvm import Advice, ManagedSpace, PrefetchStream

PAGE = 2048


def _state():
    return {
        "params": {"w": np.arange(6 * PAGE // 4, dtype=np.float32),
                   "b": np.ones(16, np.float32)},
        "opt": np.zeros(3 * PAGE, np.uint8),
    }


@pytest.mark.parametrize("ratio", [1.0, 1.5, 2.0])
@pytest.mark.parametrize("policy", ["lru", "clock"])
def test_roundtrip_under_oversubscription(ratio, policy):
    state = _state()
    total = sum(np.asarray(v).nbytes for v in
                [state["params"]["w"], state["params"]["b"], state["opt"]])
    sp = ManagedSpace(max(PAGE, int(total / ratio)), page_bytes=PAGE,
                      eviction_policy=policy)
    sp.register(state)
    got = sp.read_state()
    assert np.array_equal(got["params"]["w"], state["params"]["w"])
    assert np.array_equal(got["params"]["b"], state["params"]["b"])
    assert np.array_equal(got["opt"], state["opt"])
    # mutate + write back + peek coherently, repeatedly (forces cycling)
    for it in range(3):
        got["params"]["w"] = got["params"]["w"] + 1.0
        got["opt"] = got["opt"] + 1
        sp.write_state(got)
        peek = sp.peek_state()
        assert np.array_equal(peek["params"]["w"], state["params"]["w"] + it + 1)
        assert np.array_equal(peek["opt"], state["opt"] + it + 1)
        sp.check_invariants()
        assert sp.device_bytes_resident() <= sp.device_capacity_bytes


def test_dirty_marks_are_per_consumer_ticks():
    """Two checkpoint consumers with different last-sync ticks each see
    exactly the writes they missed — the double-buffering contract."""
    state = {"w": np.zeros(8 * PAGE // 4, np.float32)}
    sp = ManagedSpace(8 * PAGE, page_bytes=PAGE)
    sp.register(state)
    t_a = sp.tick()
    sp.write_range("w", 0, np.ones(PAGE // 4, np.float32))      # page 0
    t_b = sp.tick()
    sp.write_range("w", 3 * PAGE, np.ones(PAGE // 4, np.float32))  # page 3
    marks_a = sp.dirty_chunk_marks_since(t_a, PAGE)
    marks_b = sp.dirty_chunk_marks_since(t_b, PAGE)
    assert marks_a["w"] == [0, 3]   # consumer A missed both writes
    assert marks_b["w"] == [3]      # consumer B already saw page 0
    assert sp.dirty_chunk_marks_since(sp.tick(), PAGE)["w"] == []


def test_chunk_marks_map_pages_to_chunks():
    state = {"w": np.zeros(8 * PAGE, np.uint8)}
    sp = ManagedSpace(8 * PAGE, page_bytes=PAGE)
    sp.register(state)
    t = sp.tick()
    sp.write_range("w", 5 * PAGE, np.ones(10, np.uint8))
    # chunk = 2 pages: page 5 -> chunk 2
    assert sp.dirty_chunk_marks_since(t, 2 * PAGE)["w"] == [2]
    # chunk = half page: page 5 covers chunks 10 and 11
    assert sp.dirty_chunk_marks_since(t, PAGE // 2)["w"] == [10, 11]


def test_load_state_invalidate_not_writeback():
    state = {"w": np.zeros(4 * PAGE // 4, np.float32)}
    sp = ManagedSpace(4 * PAGE, page_bytes=PAGE)
    sp.register(state)
    sp.write_range("w", 0, np.full(PAGE // 4, 5.0, np.float32))
    new = {"w": np.full(4 * PAGE // 4, 9.0, np.float32)}
    sp.load_state(new)
    assert sp.stats.invalidations >= 1
    assert sp.stats.writebacks == 0  # superseded, not dropped
    assert np.array_equal(sp.peek_leaf("w"), new["w"])
    assert np.array_equal(sp.read_leaf("w"), new["w"])
    # a load dirties everything for every checkpoint consumer
    assert len(sp.dirty_pages_since("w", sp.tick() - 1)) == 4
    sp.check_invariants()


def test_prefetch_stream_batches():
    state = {"w": np.zeros(16 * PAGE, np.uint8)}
    sp = ManagedSpace(16 * PAGE, page_bytes=PAGE)
    sp.register(state)
    stream = PrefetchStream(batch_pages=4)
    stream.enqueue("w")  # whole leaf
    moved = stream.drain(sp)
    assert moved == 16
    assert sp.stats.prefetches == 16
    assert len(stream) == 0
    sp.read_leaf("w")
    assert sp.stats.faults == 0  # prefetch absorbed every would-be fault


def test_register_replaces_previous_regions():
    sp = ManagedSpace(8 * PAGE, page_bytes=PAGE)
    sp.register({"w": np.zeros(4 * PAGE, np.uint8)})
    sp.read_leaf("w")
    assert sp.device_bytes_resident() > 0
    sp.register({"v": np.ones(2 * PAGE, np.uint8)})
    assert sp.paths() == ["v"]
    assert sp.device_bytes_resident() == 0  # old frames released
    assert np.array_equal(sp.read_leaf("v"), np.ones(2 * PAGE, np.uint8))
    sp.check_invariants()


def test_reregistration_dirties_everything_for_old_watermarks():
    """A consumer holding a pre-registration tick must see the replaced
    content as fully dirty — register() stamps a fresh tick."""
    sp = ManagedSpace(8 * PAGE, page_bytes=PAGE)
    sp.register({"w": np.zeros(4 * PAGE, np.uint8)})
    sp.write_range("w", 0, np.ones(4, np.uint8))
    watermark = sp.tick()  # consumer synced here
    sp.register({"w": np.full(4 * PAGE, 9, np.uint8)})  # content replaced
    marks = sp.dirty_chunk_marks_since(watermark, PAGE)
    assert marks["w"] == [0, 1, 2, 3], "replaced content must be fully dirty"


def test_load_range_dirties_only_touched_pages():
    sp = ManagedSpace(8 * PAGE, page_bytes=PAGE)
    sp.register({"w": np.zeros(8 * PAGE, np.uint8)})
    sp.read_leaf("w")  # everything resident
    t = sp.tick()
    # splice 1.5 pages starting mid-page-2: pages 2 and 3 touched
    sp.load_range("w", 2 * PAGE + PAGE // 2, np.ones(PAGE + PAGE // 2, np.uint8))
    dirty = sp.dirty_pages_since("w", t).tolist()
    assert dirty == [2, 3]
    # coherence: untouched bytes intact, spliced bytes landed
    got = sp.peek_leaf("w")
    assert (got[: 2 * PAGE + PAGE // 2] == 0).all()
    assert (got[2 * PAGE + PAGE // 2 : 4 * PAGE] == 1).all()
    assert (got[4 * PAGE :] == 0).all()
    assert np.array_equal(sp.read_leaf("w"), got)
    sp.check_invariants()


def test_load_range_preserves_dirty_device_bytes_outside_splice():
    """A partially-covered resident dirty page is written back, not
    dropped: its bytes outside the splice must survive."""
    sp = ManagedSpace(8 * PAGE, page_bytes=PAGE)
    sp.register({"w": np.zeros(4 * PAGE, np.uint8)})
    sp.write_range("w", 0, np.full(PAGE, 5, np.uint8))  # page 0 dirty on device
    sp.load_range("w", PAGE // 2, np.full(PAGE // 4, 7, np.uint8))
    got = sp.peek_leaf("w")
    assert (got[: PAGE // 2] == 5).all()            # survived the write-back
    assert (got[PAGE // 2 : 3 * PAGE // 4] == 7).all()  # the splice
    assert (got[3 * PAGE // 4 : PAGE] == 5).all()
    sp.check_invariants()


def test_dirty_source_adapter_prefixes_paths():
    sp = ManagedSpace(4 * PAGE, page_bytes=PAGE)
    sp.register({"w": np.zeros(2 * PAGE, np.uint8)})
    src = sp.as_dirty_source("device/")
    t = src.tick()
    sp.write_range("w", 0, np.ones(4, np.uint8))
    marks = src.dirty_chunk_marks_since(t, PAGE)
    assert marks == {"device/w": [0]}
